//! Serve-scheduler suite: interleaved multi-job training must be
//! bitwise-identical per job to solo runs, the privacy-budget ledger
//! must stop jobs strictly within their epsilon budgets, the whole
//! scheduler must be deterministic, and a preset stop flag must retire
//! admitted jobs with truthful step-0 checkpoints.

use fastclip::coordinator::{
    checkpoint, serve, train, ClipMethod, JobSpec, ServeOptions, TrainOptions,
};
use fastclip::runtime::{Backend, NativeBackend};
use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, OnceLock};

fn native() -> &'static NativeBackend {
    static B: OnceLock<NativeBackend> = OnceLock::new();
    B.get_or_init(NativeBackend::new)
}

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fastclip_serve_{name}"));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn base_opts(config: &str, steps: u64, seed: u64, ckpt: &Path) -> TrainOptions {
    TrainOptions {
        config: config.into(),
        method: ClipMethod::Reweight,
        steps,
        dataset_n: 96,
        optimizer: "sgd".into(),
        lr: 0.05,
        log_every: 0,
        seed,
        checkpoint_dir: Some(ckpt.to_path_buf()),
        ..Default::default()
    }
}

fn params_bytes(dir: &Path) -> Vec<u8> {
    std::fs::read(dir.join("params.bin")).unwrap()
}

/// The serve acceptance gate: three jobs (two concurrent slots, so the
/// third recycles a retired job's arena — across *different* configs)
/// produce, per job, exactly the params / losses / epsilon of solo
/// `train()` runs with the same options.
#[test]
fn interleaved_serve_matches_solo_runs_bitwise() {
    let dirs_serve: Vec<PathBuf> =
        ["a", "b", "c"].iter().map(|n| tmp(&format!("mix_{n}"))).collect();
    let dirs_solo: Vec<PathBuf> =
        ["a", "b", "c"].iter().map(|n| tmp(&format!("solo_{n}"))).collect();

    let mut opt_a = base_opts("mlp2_mnist_b32", 6, 3, &dirs_serve[0]);
    opt_a.poisson = true;
    let mut opt_b = base_opts("mlp2_mnist_b32", 9, 7, &dirs_serve[1]);
    opt_b.policy =
        Some(fastclip::runtime::ClipPolicy::parse("per_layer:0.5").unwrap());
    opt_b.dataset_n = 128;
    // different model family: the pooled arena C inherits from a
    // retired job must re-layout, not reuse stale shapes
    let opt_c = base_opts("mlp4_mnist_b32", 4, 9, &dirs_serve[2]);

    let jobs: Vec<JobSpec> = [("a", &opt_a), ("b", &opt_b), ("c", &opt_c)]
        .iter()
        .map(|(n, o)| JobSpec {
            name: n.to_string(),
            opts: (*o).clone(),
            eps_budget: None,
        })
        .collect();
    let report = serve(
        native(),
        &jobs,
        &ServeOptions {
            max_concurrent: 2,
            stop: None,
        },
    )
    .unwrap();
    assert!(!report.stopped_early);
    assert_eq!(report.outcomes.len(), 3);

    for (i, opts) in [&opt_a, &opt_b, &opt_c].iter().enumerate() {
        let mut solo = (*opts).clone();
        solo.checkpoint_dir = Some(dirs_solo[i].clone());
        let solo_rep = train(native(), &solo).unwrap();
        let o = &report.outcomes[i];
        assert!(!o.budget_stopped);
        assert_eq!(o.report.steps, solo_rep.steps, "job {}", o.name);
        let lb = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            lb(&o.report.losses),
            lb(&solo_rep.losses),
            "job {}: interleaving changed the loss stream",
            o.name
        );
        assert_eq!(
            params_bytes(&dirs_serve[i]),
            params_bytes(&dirs_solo[i]),
            "job {}: interleaving changed the final parameters",
            o.name
        );
        let (es, os_) = o.report.epsilon.unwrap();
        let (el, ol) = solo_rep.epsilon.unwrap();
        assert_eq!(es.to_bits(), el.to_bits(), "job {}", o.name);
        assert_eq!(os_, ol);
    }
    for d in dirs_serve.iter().chain(&dirs_solo) {
        std::fs::remove_dir_all(d).ok();
    }
}

/// The global budget ledger: two identical jobs with different epsilon
/// budgets both get refused before their step cap, the tighter budget
/// first, and each job's *spent* epsilon stays within its budget —
/// the refused step is never charged.
#[test]
fn ledger_stops_smaller_budget_job_first() {
    let d_tight = tmp("budget_tight");
    let d_loose = tmp("budget_loose");
    let mk = |seed: u64, ckpt: &Path| {
        let mut o = base_opts("mlp2_mnist_b32", 400, seed, ckpt);
        o.dataset_n = 128; // q = 0.25: spend grows fast enough to test
        o.sigma = 1.0;
        o
    };
    let jobs = vec![
        JobSpec {
            name: "tight".into(),
            opts: mk(1, &d_tight),
            eps_budget: Some(2.0),
        },
        JobSpec {
            name: "loose".into(),
            opts: mk(1, &d_loose),
            eps_budget: Some(4.0),
        },
    ];
    let report = serve(
        native(),
        &jobs,
        &ServeOptions {
            max_concurrent: 0,
            stop: None,
        },
    )
    .unwrap();
    assert!(!report.stopped_early);
    let tight = &report.outcomes[0];
    let loose = &report.outcomes[1];
    assert!(tight.budget_stopped, "tight job ran all {} steps", tight.report.steps);
    assert!(loose.budget_stopped, "loose job ran all {} steps", loose.report.steps);
    assert!(
        tight.report.steps < loose.report.steps,
        "tighter budget must stop first: {} vs {}",
        tight.report.steps,
        loose.report.steps
    );
    assert!(loose.report.steps < 400);
    let (e_t, _) = tight.report.epsilon.unwrap();
    let (e_l, _) = loose.report.epsilon.unwrap();
    assert!(e_t <= 2.0 + 1e-9, "tight job overspent: eps={e_t}");
    assert!(e_l <= 4.0 + 1e-9, "loose job overspent: eps={e_l}");
    // the refusal checkpoint records the truthful stop step — a valid
    // resume point strictly within budget
    let cfg = native().manifest().config("mlp2_mnist_b32").unwrap();
    let (meta, _) = checkpoint::load(&d_tight, cfg).unwrap();
    assert_eq!(meta.step, tight.report.steps);
    for d in [&d_tight, &d_loose] {
        std::fs::remove_dir_all(d).ok();
    }
}

/// Scheduler determinism: the same jobs file semantics twice in a row
/// (fresh checkpoint dirs) produce identical losses and identical
/// checkpoint bytes — regardless of rayon pool width (CI pins
/// RAYON_NUM_THREADS=2; local runs use the default).
#[test]
fn serve_is_deterministic_across_runs() {
    let run = |tag: &str| {
        let da = tmp(&format!("det_{tag}_a"));
        let db = tmp(&format!("det_{tag}_b"));
        let mut oa = base_opts("mlp2_mnist_b32", 5, 21, &da);
        oa.poisson = true;
        let ob = base_opts("mlp2_mnist_b32", 7, 22, &db);
        let jobs = vec![
            JobSpec {
                name: "a".into(),
                opts: oa,
                eps_budget: None,
            },
            JobSpec {
                name: "b".into(),
                opts: ob,
                eps_budget: None,
            },
        ];
        let rep = serve(
            native(),
            &jobs,
            &ServeOptions {
                max_concurrent: 2,
                stop: None,
            },
        )
        .unwrap();
        let losses: Vec<Vec<u32>> = rep
            .outcomes
            .iter()
            .map(|o| o.report.losses.iter().map(|x| x.to_bits()).collect())
            .collect();
        let params = (params_bytes(&da), params_bytes(&db));
        for d in [&da, &db] {
            std::fs::remove_dir_all(d).ok();
        }
        (losses, params)
    };
    let first = run("one");
    let second = run("two");
    assert_eq!(first, second, "serve is not deterministic across runs");
}

/// A stop flag set before `serve()` begins: the first `max_concurrent`
/// jobs are still admitted (and get truthful step-0 checkpoints), the
/// rest never start, and the report says so.
#[test]
fn preset_stop_flag_retires_admitted_jobs_at_step_zero() {
    let dirs: Vec<PathBuf> =
        ["a", "b", "c"].iter().map(|n| tmp(&format!("pre_{n}"))).collect();
    let jobs: Vec<JobSpec> = dirs
        .iter()
        .enumerate()
        .map(|(i, d)| JobSpec {
            name: format!("job{i}"),
            opts: base_opts("mlp2_mnist_b32", 10, i as u64, d),
            eps_budget: None,
        })
        .collect();
    let report = serve(
        native(),
        &jobs,
        &ServeOptions {
            max_concurrent: 2,
            stop: Some(Arc::new(AtomicBool::new(true))),
        },
    )
    .unwrap();
    assert!(report.stopped_early);
    // two admitted (admission precedes the stop check), one skipped
    assert_eq!(report.outcomes.len(), 2);
    let cfg = native().manifest().config("mlp2_mnist_b32").unwrap();
    for (i, o) in report.outcomes.iter().enumerate() {
        assert_eq!(o.report.steps, 0);
        assert!(!o.budget_stopped);
        let (meta, _) = checkpoint::load(&dirs[i], cfg).unwrap();
        assert_eq!(meta.step, 0);
    }
    assert!(!dirs[2].exists(), "unstarted job must not write a checkpoint");
    for d in &dirs {
        std::fs::remove_dir_all(d).ok();
    }
}
