//! Ablation (§Perf L2): reweight (the paper's two backward passes,
//! Alg 1) vs reweight_direct (our extension: the weighted gradient is
//! assembled from the SAME tapped intermediates that produced the
//! norms — one backward pass total).
//!
//! Expected: direct wins by up to ~1.5-2x on models where the backward
//! pass dominates (MLP, CNN); both remain exactly
//! gradient-equivalent (tested in test_clipping.py).

use fastclip::bench::driver::{bench_backend, StepRunner};
use fastclip::bench::{BenchOpts, Suite};
use fastclip::coordinator::ClipMethod;
use fastclip::runtime::Backend;

fn main() -> anyhow::Result<()> {
    let engine = bench_backend();
    let mut suite = Suite::new("ablation_direct");

    let configs = [
        "mlp2_mnist_b32",
        "mlp2_mnist_b128",
        "cnn_mnist_b32",
        "cnn_mnist_b128",
        "rnn_mnist_b32",
        "lstm_mnist_b32",
        "transformer_imdb_b32",
    ];
    let mut rows = Vec::new();
    for config in configs {
        let cfg = engine.manifest().config(config)?;
        if !cfg.artifacts.contains_key("reweight_direct") {
            eprintln!("  (skip {config}: no reweight_direct artifact)");
            continue;
        }
        for (label, method) in [
            ("2-backward (paper)", ClipMethod::Reweight),
            ("1-backward (direct)", ClipMethod::ReweightDirect),
            ("nonprivate floor", ClipMethod::NonPrivate),
        ] {
            let mut runner = StepRunner::new(&engine, config, method)?;
            let name = format!("{config}/{label}");
            let r = suite.bench(&name, BenchOpts::default(), || runner.step());
            rows.push((config, label, r.summary.mean));
        }
    }

    println!("\n| config | paper ms | direct ms | direct speedup | dp overhead vs nonprivate |");
    println!("|---|---:|---:|---:|---:|");
    for config in configs {
        let get = |l: &str| {
            rows.iter()
                .find(|(c, lab, _)| *c == config && *lab == l)
                .map(|(_, _, t)| *t * 1e3)
        };
        if let (Some(p), Some(d), Some(n)) = (
            get("2-backward (paper)"),
            get("1-backward (direct)"),
            get("nonprivate floor"),
        ) {
            println!(
                "| {} | {:.2} | {:.2} | {:.2}x | {:.2}x |",
                config,
                p,
                d,
                p / d,
                d / n
            );
        }
    }
    suite.finish()
}
