//! Figure 5: per-epoch training time across the five architectures
//! (MLP/CNN/RNN/LSTM on MNIST; Transformer on IMDB), batch 32, for
//! Non-private / nxBP / multiLoss / ReweightGP.
//!
//! The paper reports seconds per epoch on a 1080 Ti; we report per-step
//! means on XLA-CPU plus the per-epoch extrapolation at the paper's
//! dataset sizes. The *shape* to reproduce: ReweightGP within a small
//! factor of Non-private; nxBP one-to-two orders of magnitude slower.

use fastclip::bench::driver::{bench_backend, figure_methods, per_epoch_seconds, StepRunner};
use fastclip::bench::{BenchOpts, Suite};
use fastclip::coordinator::ClipMethod;

fn main() -> anyhow::Result<()> {
    let engine = bench_backend();
    let mut suite = Suite::new("fig5_architectures");

    // (config, paper dataset size for the per-epoch extrapolation)
    let configs = [
        ("mlp2_mnist_b32", 60_000),
        ("cnn_mnist_b32", 60_000),
        ("rnn_mnist_b32", 60_000),
        ("lstm_mnist_b32", 60_000),
        ("transformer_imdb_b32", 25_000),
    ];

    let mut rows = Vec::new();
    for (config, n) in configs {
        for method in figure_methods() {
            let mut runner = StepRunner::new(&engine, config, method)?;
            let opts = if method == ClipMethod::NxBp {
                BenchOpts::heavy()
            } else {
                BenchOpts::default()
            };
            let name = format!("{config}/{}", method.name());
            let r = suite.bench(&name, opts, || runner.step());
            rows.push((config, n, method, r.summary.mean));
        }
    }

    // per-epoch extrapolation + speedups (the paper's headline format)
    println!("\n| architecture | method | step ms | est. epoch s | speedup vs nxBP |");
    println!("|---|---|---:|---:|---:|");
    for (config, n, method, mean) in &rows {
        let nxbp = rows
            .iter()
            .find(|(c, _, m, _)| c == config && *m == ClipMethod::NxBp)
            .map(|(_, _, _, t)| *t)
            .unwrap();
        println!(
            "| {} | {} | {:.3} | {:.1} | {:.1}x |",
            config,
            method.name(),
            mean * 1e3,
            per_epoch_seconds(*mean, *n, 32),
            nxbp / mean
        );
    }
    suite.finish()
}
