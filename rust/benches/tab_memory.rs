//! Sec 6.7 (memory): "largest batch before OOM" via the analytic
//! model + real measured peak-RSS deltas around actual runs.
//!
//! Paper reference points (ResNet101 @ 256px, 11 GiB): non-private
//! fails at 48, ReweightGP at 36 (~25% overhead), multiLoss at 18;
//! nxBP is batch-size-insensitive. ReweightGP on ResNet18 @ 32px ran
//! at batch 500.

use fastclip::bench::driver::{bench_backend, StepRunner};
use fastclip::bench::Suite;
use fastclip::coordinator::{memory, ClipMethod};
use fastclip::runtime::Backend;
use fastclip::util;

fn main() -> anyhow::Result<()> {
    let engine = bench_backend();
    let mut suite = Suite::new("tab_memory");

    // ---- 1. analytic model at paper scale ---------------------------
    println!("## analytic max-batch (11 GiB budget)\n");
    println!("| footprint | nonprivate | reweight | multiloss | nxbp |");
    println!("|---|---:|---:|---:|---:|");
    let scenarios = [
        ("resnet101 @256px (paper)", memory::Footprint {
            p: 44_000_000,
            a: 60_000_000,
            i: 3 * 256 * 256,
        }),
        ("resnet18 @32px (paper lower end)", memory::Footprint {
            p: 11_000_000,
            a: 1_500_000,
            i: 3 * 32 * 32,
        }),
    ];
    for (label, fp) in scenarios {
        let mb = |m: &str| memory::max_batch(m, fp, 11 << 30);
        println!(
            "| {} | {} | {} | {} | {} |",
            label,
            mb("nonprivate"),
            mb("reweight"),
            mb("multiloss"),
            mb("nxbp")
        );
    }

    // ---- 2. model applied to our actual configs ---------------------
    println!("\n## analytic max-batch for repo configs (2 GiB budget)\n");
    println!("| config | nonprivate | reweight | multiloss | nxbp |");
    println!("|---|---:|---:|---:|---:|");
    for name in [
        "resnet_mini_lsun64_b8",
        "vgg_mini_lsun64_b8",
        "cnn_mnist_b32",
        "mlp2_mnist_b32",
    ] {
        let cfg = engine.manifest().config(name)?;
        let fp = memory::Footprint::of(cfg, cfg.act_elems_per_example as u64);
        let mb = |m: &str| memory::max_batch(m, fp, 2 << 30);
        println!(
            "| {} | {} | {} | {} | {} |",
            name,
            mb("nonprivate"),
            mb("reweight"),
            mb("multiloss"),
            mb("nxbp")
        );
    }

    // ---- 3. measured peak RSS deltas around real runs ---------------
    println!("\n## measured peak-RSS growth while running each method\n");
    let config = "resnet_mini_lsun64_b8";
    println!("(config {config}; RSS is cumulative — methods run in increasing-footprint order)\n");
    for method in [
        ClipMethod::NxBp,
        ClipMethod::NonPrivate,
        ClipMethod::Reweight,
        ClipMethod::MultiLoss,
    ] {
        let before = util::peak_rss_bytes().unwrap_or(0);
        let mut runner = StepRunner::new(&engine, config, method)?;
        for _ in 0..3 {
            runner.step();
        }
        let after = util::peak_rss_bytes().unwrap_or(0);
        let delta = after.saturating_sub(before);
        println!(
            "  {:<11} peak RSS {} (+{})",
            method.name(),
            util::fmt_bytes(after),
            util::fmt_bytes(delta)
        );
        suite.record(
            &format!("{config}/{}/rss_delta", method.name()),
            delta as f64 / 1e6, // store MB in the ms field; noted
            vec![("unit".into(), "MB (not ms)".into())],
        );
    }
    suite.finish()
}
