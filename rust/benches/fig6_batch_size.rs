//! Figure 6: per-epoch training time vs batch size {16,32,64,128} for
//! MLP / CNN / RNN on MNIST.
//!
//! Shape to reproduce (paper Sec 6.3): Non-private and ReweightGP
//! per-epoch time *decreases* with batch size (more parallelism);
//! nxBP stays flat (backprop runs once per example regardless).

use fastclip::bench::driver::{bench_backend, figure_methods, per_epoch_seconds, StepRunner};
use fastclip::bench::{BenchOpts, Suite};
use fastclip::coordinator::ClipMethod;

fn main() -> anyhow::Result<()> {
    let engine = bench_backend();
    let mut suite = Suite::new("fig6_batch_size");
    let n_dataset = 60_000;

    let mut rows = Vec::new();
    for model in ["mlp2", "cnn", "rnn"] {
        for batch in [16usize, 32, 64, 128] {
            let config = format!("{model}_mnist_b{batch}");
            for method in figure_methods() {
                // nxBP cost is batch-size independent per *example*;
                // time it once per model at b=16 and reuse (paper: it
                // loops the same batch-1 backward).
                if method == ClipMethod::NxBp && batch != 16 {
                    continue;
                }
                let opts = if method == ClipMethod::NxBp {
                    BenchOpts::heavy()
                } else {
                    BenchOpts::default()
                };
                let mut runner = StepRunner::new(&engine, &config, method)?;
                let name = format!("{config}/{}", method.name());
                let r = suite.bench(&name, opts, || runner.step());
                rows.push((model, batch, method, r.summary.mean));
            }
        }
    }

    println!("\n| model | batch | method | est. epoch s |");
    println!("|---|---:|---|---:|");
    for model in ["mlp2", "cnn", "rnn"] {
        // nxBP per-example time from the b=16 measurement
        let nx_per_example = rows
            .iter()
            .find(|(m, _, meth, _)| *m == model && *meth == ClipMethod::NxBp)
            .map(|(_, b, _, t)| t / *b as f64)
            .unwrap();
        for batch in [16usize, 32, 64, 128] {
            for method in figure_methods() {
                let epoch_s = if method == ClipMethod::NxBp {
                    nx_per_example * n_dataset as f64
                } else {
                    let t = rows
                        .iter()
                        .find(|(m, b, meth, _)| {
                            *m == model && *b == batch && *meth == method
                        })
                        .map(|(_, _, _, t)| *t)
                        .unwrap();
                    per_epoch_seconds(t, n_dataset, batch)
                };
                println!(
                    "| {} | {} | {} | {:.1} |",
                    model,
                    batch,
                    method.name(),
                    epoch_s
                );
            }
        }
    }
    suite.finish()
}
