//! Figure 7: per-epoch time vs number of hidden layers {2,4,6,8} for
//! MLPs on MNIST, FMNIST, CIFAR10 at batch 128 — the experiment behind
//! the paper's headline "54x-94x speedup over naive per-example
//! clipping at batch 128".
//!
//! FMNIST shares MNIST's shapes, so it runs the MNIST-shaped artifact
//! on FMNIST data (timing is shape-determined; DESIGN.md §5).

use fastclip::bench::driver::{bench_backend, per_epoch_seconds, StepRunner};
use fastclip::bench::{speedup, BenchOpts, Suite};
use fastclip::coordinator::ClipMethod;

fn main() -> anyhow::Result<()> {
    let engine = bench_backend();
    let mut suite = Suite::new("fig7_depth");
    let methods = [
        ClipMethod::NonPrivate,
        ClipMethod::Reweight,
        ClipMethod::MultiLoss,
        ClipMethod::NxBp,
    ];

    // (dataset label, artifact dataset, n for epoch extrapolation)
    let datasets = [
        ("mnist", "mnist", 60_000usize),
        ("fmnist", "mnist", 60_000),
        ("cifar10", "cifar10", 50_000),
    ];

    let mut rows = Vec::new();
    for (label, artifact_ds, n) in datasets {
        for depth in [2usize, 4, 6, 8] {
            let config = format!("mlp{depth}_{artifact_ds}_b128");
            for method in methods {
                let opts = if method == ClipMethod::NxBp {
                    BenchOpts::heavy()
                } else {
                    BenchOpts::default()
                };
                let mut runner = StepRunner::with_dataset(
                    &engine,
                    &config,
                    method,
                    Some(label),
                )?;
                let name = format!("mlp{depth}_{label}_b128/{}", method.name());
                let r = suite.bench(&name, opts, || runner.step());
                rows.push((label, depth, method, n, r.summary.mean));
            }
        }
    }

    println!("\n| dataset | depth | reweight epoch s | nxbp epoch s | speedup |");
    println!("|---|---:|---:|---:|---:|");
    let mut best: f64 = 0.0;
    for (label, _, n) in datasets {
        for depth in [2usize, 4, 6, 8] {
            let get = |m: ClipMethod| {
                rows.iter()
                    .find(|(l, d, meth, _, _)| {
                        *l == label && *d == depth && *meth == m
                    })
                    .map(|(_, _, _, _, t)| *t)
                    .unwrap()
            };
            let rw = get(ClipMethod::Reweight);
            let nx = get(ClipMethod::NxBp);
            let s = speedup(nx, rw);
            best = best.max(s);
            println!(
                "| {} | {} | {:.1} | {:.1} | {:.1}x |",
                label,
                depth,
                per_epoch_seconds(rw, n, 128),
                per_epoch_seconds(nx, n, 128),
                s
            );
        }
    }
    println!("\nheadline: max ReweightGP speedup over nxBP at batch 128 = {best:.1}x");
    println!("(paper reports 54x-94x on a 1080 Ti; shape, not absolute, is the target)");
    suite.finish()
}
