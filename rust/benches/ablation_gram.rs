//! Ablation: recurrent/sequence per-example gradient norms —
//! materialized (paper Alg 4: build G_i = sum_t dz_t (x) h_t, then
//! norm) vs our Gram-matrix extension (norm via <dZ dZ^T, H H^T>
//! without materializing G_i).
//!
//! The Gram trick wins when T^2 << m*n (DESIGN.md §6): for the paper's
//! RNN (T=28, m=n=128) it does ~7x less work per layer; for short
//! sequences with wide layers the gap widens further.

use fastclip::bench::driver::{bench_backend, StepRunner};
use fastclip::bench::{BenchOpts, Suite};
use fastclip::coordinator::ClipMethod;

fn main() -> anyhow::Result<()> {
    let engine = bench_backend();
    let mut suite = Suite::new("ablation_gram");

    let configs = ["rnn_mnist_b32", "lstm_mnist_b32", "transformer_imdb_b32"];
    let mut rows = Vec::new();
    for config in configs {
        for (label, method) in [
            ("materialize", ClipMethod::Reweight),
            ("gram", ClipMethod::ReweightGram),
        ] {
            let mut runner = StepRunner::new(&engine, config, method)?;
            let name = format!("{config}/{label}");
            let r = suite.bench(&name, BenchOpts::default(), || runner.step());
            rows.push((config, label, r.summary.mean));
        }
    }

    println!("\n| config | materialize ms | gram ms | gram speedup |");
    println!("|---|---:|---:|---:|");
    for config in configs {
        let get = |l: &str| {
            rows.iter()
                .find(|(c, lab, _)| *c == config && *lab == l)
                .map(|(_, _, t)| *t * 1e3)
                .unwrap()
        };
        println!(
            "| {} | {:.2} | {:.2} | {:.2}x |",
            config,
            get("materialize"),
            get("gram"),
            get("materialize") / get("gram")
        );
    }
    suite.finish()
}
