//! Figure 8: deep conv nets (ResNetMini / VGGMini stand-ins for
//! ResNet18/101, VGG11/16 — DESIGN.md §5) on LSUN-like images at two
//! sizes, small batch.
//!
//! Shape to reproduce: ReweightGP beats nxBP and multiLoss everywhere;
//! the advantage shrinks as image size grows; multiLoss hits the
//! memory wall first (reported via the analytic model — CPU doesn't
//! OOM — as the paper's "missing bar").

use fastclip::bench::driver::{bench_backend, figure_methods, StepRunner};
use fastclip::bench::{speedup, BenchOpts, Suite};
use fastclip::coordinator::{memory, ClipMethod};

fn main() -> anyhow::Result<()> {
    let engine = bench_backend();
    let mut suite = Suite::new("fig8_deep_nets");

    let configs = [
        "resnet_mini_lsun32_b8",
        "resnet_mini_lsun64_b8",
        "vgg_mini_lsun32_b8",
        "vgg_mini_lsun64_b8",
    ];

    let mut rows = Vec::new();
    for config in configs {
        for method in figure_methods() {
            let opts = if method == ClipMethod::NxBp {
                BenchOpts::heavy()
            } else {
                BenchOpts::default()
            };
            let mut runner = StepRunner::new(&engine, config, method)?;
            let name = format!("{config}/{}", method.name());
            let r = suite.bench(&name, opts, || runner.step());
            rows.push((config, method, r.summary.mean));
        }
    }

    println!("\n| net | reweight ms | multiloss ms | nxbp ms | rw speedup vs nxbp |");
    println!("|---|---:|---:|---:|---:|");
    for config in configs {
        let get = |m: ClipMethod| {
            rows.iter()
                .find(|(c, meth, _)| *c == config && *meth == m)
                .map(|(_, _, t)| *t * 1e3)
                .unwrap()
        };
        println!(
            "| {} | {:.2} | {:.2} | {:.2} | {:.1}x |",
            config,
            get(ClipMethod::Reweight),
            get(ClipMethod::MultiLoss),
            get(ClipMethod::NxBp),
            speedup(get(ClipMethod::NxBp), get(ClipMethod::Reweight)),
        );
    }

    // the paper's missing multiLoss bars: analytic memory wall at a
    // GPU-sized budget for a paper-scale network footprint
    println!("\nmemory wall (analytic, 11 GiB budget, ResNet101-scale footprint):");
    let fp = memory::Footprint { p: 44_000_000, a: 60_000_000, i: 3 * 256 * 256 };
    for m in ["nonprivate", "reweight", "multiloss", "nxbp"] {
        println!(
            "  {:<11} max batch = {}",
            m,
            memory::max_batch(m, fp, 11 << 30)
        );
    }
    suite.finish()
}
