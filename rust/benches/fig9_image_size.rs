//! Figure 9: processing time vs image resolution for the ResNet
//! stand-in at batch 16 (paper: ResNet18, 32px -> 256px; here
//! 16px -> 64px, same quadratic activation growth).
//!
//! Shape to reproduce: ReweightGP's advantage over nxBP *decreases*
//! with image size — the extra per-layer norm work scales with the
//! (quadratically growing) activation maps.

use fastclip::bench::driver::{bench_backend, StepRunner};
use fastclip::bench::{speedup, BenchOpts, Suite};
use fastclip::coordinator::ClipMethod;

fn main() -> anyhow::Result<()> {
    let engine = bench_backend();
    let mut suite = Suite::new("fig9_image_size");

    let methods = [
        ClipMethod::NonPrivate,
        ClipMethod::Reweight,
        ClipMethod::MultiLoss,
        ClipMethod::NxBp,
    ];

    let mut rows = Vec::new();
    for img in [16usize, 32, 48, 64] {
        let config = format!("resnet_mini_lsun{img}_b16");
        for method in methods {
            let opts = if method == ClipMethod::NxBp {
                BenchOpts::heavy()
            } else {
                BenchOpts::default()
            };
            let mut runner = StepRunner::new(&engine, &config, method)?;
            let name = format!("{img}px/{}", method.name());
            let r = suite.bench(&name, opts, || runner.step());
            rows.push((img, method, r.summary.mean));
        }
    }

    println!("\n| image | nonprivate ms | reweight ms | nxbp ms | rw/np overhead | rw speedup vs nxbp |");
    println!("|---|---:|---:|---:|---:|---:|");
    let mut speedups = Vec::new();
    for img in [16usize, 32, 48, 64] {
        let get = |m: ClipMethod| {
            rows.iter()
                .find(|(i, meth, _)| *i == img && *meth == m)
                .map(|(_, _, t)| *t * 1e3)
                .unwrap()
        };
        let s = speedup(get(ClipMethod::NxBp), get(ClipMethod::Reweight));
        speedups.push((img, s));
        println!(
            "| {}px | {:.2} | {:.2} | {:.2} | {:.2}x | {:.1}x |",
            img,
            get(ClipMethod::NonPrivate),
            get(ClipMethod::Reweight),
            get(ClipMethod::NxBp),
            get(ClipMethod::Reweight) / get(ClipMethod::NonPrivate),
            s
        );
    }
    println!(
        "\nadvantage trend (paper: decreasing with resolution): {}",
        speedups
            .iter()
            .map(|(i, s)| format!("{i}px={s:.1}x"))
            .collect::<Vec<_>>()
            .join("  ")
    );
    suite.finish()
}
