//! Ablation: kernel backend for the ReweightGP norm computation —
//! pure-jnp (XLA-fused, the CPU production path) vs Pallas kernels
//! under interpret=True (the TPU-authored path).
//!
//! On CPU the interpret-mode Pallas path pays an emulation tax (the
//! grid becomes an XLA while-loop); the ablation quantifies it and
//! proves both backends produce the same training step (equivalence is
//! separately asserted in the test suites). On a real TPU the Pallas
//! path is the one that reaches the MXU — see DESIGN.md
//! §Hardware-Adaptation for the static VMEM/MXU analysis.

use fastclip::bench::driver::{bench_backend, StepRunner};
use fastclip::bench::{BenchOpts, Suite};
use fastclip::coordinator::ClipMethod;

fn main() -> anyhow::Result<()> {
    let engine = bench_backend();
    let mut suite = Suite::new("ablation_kernels");

    let configs = ["mlp2_mnist_b32", "cnn_mnist_b32", "transformer_imdb_b32"];
    let mut rows = Vec::new();
    for config in configs {
        for (label, method) in [
            ("jnp", ClipMethod::Reweight),
            ("pallas", ClipMethod::ReweightPallas),
        ] {
            let mut runner = StepRunner::new(&engine, config, method)?;
            let name = format!("{config}/{label}");
            let r = suite.bench(&name, BenchOpts::default(), || runner.step());
            rows.push((config, label, r.summary.mean));
        }
    }

    println!("\n| config | jnp ms | pallas(interpret) ms | interpret tax |");
    println!("|---|---:|---:|---:|");
    for config in configs {
        let get = |l: &str| {
            rows.iter()
                .find(|(c, lab, _)| *c == config && *lab == l)
                .map(|(_, _, t)| *t * 1e3)
                .unwrap()
        };
        println!(
            "| {} | {:.2} | {:.2} | {:.2}x |",
            config,
            get("jnp"),
            get("pallas"),
            get("pallas") / get("jnp")
        );
    }
    suite.finish()
}
