"""The paper's five benchmark architectures (Sec 6.1.1) plus the deep
conv nets of Secs 6.5/6.6 in reduced form (DESIGN.md §5 substitutions).

Every model is a `Model`: an ordered set of parameter specs plus a
tape-aware forward. `loss_per_example` is the quantity the paper clips;
everything in clipping.py / baselines.py is generic over Model.
"""

import jax
import jax.numpy as jnp

from . import layers as L


class Model:
    """A named architecture: parameter specs + tape-aware forward."""

    def __init__(self, name):
        self.name = name
        self._layers = []

    def add(self, layer):
        self._layers.append(layer)
        return layer

    # -- parameters -------------------------------------------------
    def param_specs(self):
        specs = []
        for layer in self._layers:
            specs.extend(layer.param_specs())
        return specs

    def param_names(self):
        return [s.name for s in self.param_specs()]

    def init_params(self, seed=0):
        """Deterministic init; returns params as a flat list (the HLO
        argument order recorded in the manifest)."""
        key = jax.random.PRNGKey(seed)
        out = []
        for spec in self.param_specs():
            key, sub = jax.random.split(key)
            out.append(spec.init(sub, spec.shape))
        return out

    def params_dict(self, params_list):
        names = self.param_names()
        assert len(names) == len(params_list), (
            f"{self.name}: expected {len(names)} params, got {len(params_list)}"
        )
        return dict(zip(names, params_list))

    # -- forward / loss ---------------------------------------------
    def forward(self, p, x, tape):
        raise NotImplementedError

    def loss_per_example(self, params_list, x, y, tape=None):
        tape = tape or L.Tape.off()
        logits = self.forward(self.params_dict(params_list), x, tape)
        return L.cross_entropy_per_example(logits, y)

    def loss_sum(self, params_list, x, y, tape=None):
        return jnp.sum(self.loss_per_example(params_list, x, y, tape))

    def loss_mean(self, params_list, x, y):
        return jnp.mean(self.loss_per_example(params_list, x, y))

    def eval_metrics(self, params_list, x, y):
        """(mean loss, correct count) — the `fwd` artifact."""
        logits = self.forward(self.params_dict(params_list), x, L.Tape.off())
        loss = jnp.mean(L.cross_entropy_per_example(logits, y))
        return loss, L.accuracy_count(logits, y)


class MLP(Model):
    """Paper Sec 6.1.1: two hidden layers (128, 256), sigmoid.

    Depth variants for Fig 7 alternate 128/256 hidden units.
    """

    def __init__(self, in_dim, n_classes=10, hidden=None, depth=2):
        super().__init__(f"mlp{depth}")
        if hidden is None:
            hidden = [128 if i % 2 == 0 else 256 for i in range(depth)]
        dims = [in_dim] + hidden + [n_classes]
        self.fcs = [
            self.add(L.Linear(f"fc{i}", dims[i], dims[i + 1]))
            for i in range(len(dims) - 1)
        ]

    def forward(self, p, x, tape):
        x = x.reshape(x.shape[0], -1)
        for fc in self.fcs[:-1]:
            x = jax.nn.sigmoid(fc(p, x, tape))
        return self.fcs[-1](p, x, tape)


class CNN(Model):
    """Paper Sec 6.1.1: conv(20@5x5) -> 2x2 maxpool -> conv(50@5x5)
    -> 2x2 maxpool -> fc(128) -> fc(classes). No zero padding."""

    def __init__(self, c_in=1, img=28, n_classes=10):
        super().__init__("cnn")
        self.conv1 = self.add(L.Conv2d("conv1", c_in, 20, 5))
        self.conv2 = self.add(L.Conv2d("conv2", 20, 50, 5))
        s = (img - 4) // 2  # after conv1 + pool
        s = (s - 4) // 2  # after conv2 + pool
        self.flat = 50 * s * s
        self.fc1 = self.add(L.Linear("fc1", self.flat, 128))
        self.fc2 = self.add(L.Linear("fc2", 128, n_classes))

    def forward(self, p, x, tape):
        x = jax.nn.relu(self.conv1(p, x, tape))
        x = L.max_pool_2x2(x)
        x = jax.nn.relu(self.conv2(p, x, tape))
        x = L.max_pool_2x2(x)
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(self.fc1(p, x, tape))
        return self.fc2(p, x, tape)


class RNNModel(Model):
    """Paper Sec 6.1.1: one vanilla recurrent layer (128, tanh) + fc.
    Images are consumed row-by-row as a length-H sequence."""

    def __init__(self, n_in=28, n_hidden=128, n_classes=10):
        super().__init__("rnn")
        self.rnn = self.add(L.RNN("rnn", n_in, n_hidden))
        self.fc = self.add(L.Linear("fc", n_hidden, n_classes))

    def forward(self, p, x, tape):
        if x.ndim == 4:  # [tau, 1, H, W] image -> row sequence
            x = x[:, 0, :, :]
        h = self.rnn(p, x, tape)
        return self.fc(p, h, tape)


class LSTMModel(Model):
    """Paper Sec 6.1.1: one LSTM layer (128) + fc."""

    def __init__(self, n_in=28, n_hidden=128, n_classes=10):
        super().__init__("lstm")
        self.lstm = self.add(L.LSTM("lstm", n_in, n_hidden))
        self.fc = self.add(L.Linear("fc", n_hidden, n_classes))

    def forward(self, p, x, tape):
        if x.ndim == 4:
            x = x[:, 0, :, :]
        h = self.lstm(p, x, tape)
        return self.fc(p, h, tape)


class Transformer(Model):
    """Paper Sec 6.1.1 / Fig 4: frozen embedding + positional encoding
    + one encoder block (MHA -> add&norm -> FFN -> add&norm) + fc.

    Embeddings are frozen (the paper uses pretrained GloVe), so they
    carry no per-example gradients — matching the paper's setup.
    """

    def __init__(self, vocab=5000, seq=64, d_model=64, n_heads=2,
                 d_ff=128, n_classes=2):
        super().__init__("transformer")
        self.seq, self.d_model = seq, d_model
        self.embed = self.add(L.Embedding("embed", vocab, d_model))
        self.pe = L.positional_encoding(seq, d_model)
        self.mha = self.add(L.MultiHeadAttention("mha", d_model, n_heads))
        self.ln1 = self.add(L.LayerNorm("ln1", d_model))
        self.ff1 = self.add(L.Linear("ff1", d_model, d_ff))
        self.ff2 = self.add(L.Linear("ff2", d_ff, d_model))
        self.ln2 = self.add(L.LayerNorm("ln2", d_model))
        self.fc = self.add(L.Linear("fc", d_model, n_classes))

    def forward(self, p, x, tape):
        # x: [tau, seq] int32 token ids
        h = self.embed(p, x, tape) + self.pe
        a = self.mha(p, h, tape)
        h = self.ln1(p, h + a, tape)
        f = self.ff2(p, jax.nn.relu(self.ff1(p, h, tape)), tape)
        h = self.ln2(p, h + f, tape)
        h = jnp.mean(h, axis=1)  # mean-pool over sequence
        return self.fc(p, h, tape)


class _FrozenNorm:
    """Frozen batch-norm stand-in (paper Sec 6.5 freezes BN params:
    they have no per-example gradients). A parameterless affine with
    fixed scale/shift constants."""

    def __init__(self, scale=1.0, shift=0.0):
        self.scale, self.shift = scale, shift

    def __call__(self, x):
        return self.scale * x + self.shift


class ResNetMini(Model):
    """Reduced ResNet (Figs 8, 9): stem conv + two residual blocks with
    a 2x2-pool transition, frozen norms, global average pool, fc head.
    Preserves the layer mix (conv stacks, skip adds, frozen norm) whose
    per-layer cost the paper studies vs image size."""

    def __init__(self, c_in=3, img=32, width=8, n_classes=10):
        super().__init__("resnet_mini")
        w = width
        self.norm = _FrozenNorm()
        self.stem = self.add(L.Conv2d("stem", c_in, w, 3, padding=1))
        self.b1a = self.add(L.Conv2d("b1a", w, w, 3, padding=1))
        self.b1b = self.add(L.Conv2d("b1b", w, w, 3, padding=1))
        self.trans = self.add(L.Conv2d("trans", w, 2 * w, 3, padding=1))
        self.b2a = self.add(L.Conv2d("b2a", 2 * w, 2 * w, 3, padding=1))
        self.b2b = self.add(L.Conv2d("b2b", 2 * w, 2 * w, 3, padding=1))
        self.fc = self.add(L.Linear("fc", 2 * w, n_classes))

    def forward(self, p, x, tape):
        x = jax.nn.relu(self.norm(self.stem(p, x, tape)))
        r = x
        x = jax.nn.relu(self.norm(self.b1a(p, x, tape)))
        x = self.norm(self.b1b(p, x, tape))
        x = jax.nn.relu(x + r)  # skip connection (Sec 5.7)
        x = L.max_pool_2x2(x)
        x = jax.nn.relu(self.norm(self.trans(p, x, tape)))
        r = x
        x = jax.nn.relu(self.norm(self.b2a(p, x, tape)))
        x = self.norm(self.b2b(p, x, tape))
        x = jax.nn.relu(x + r)
        x = L.avg_pool_global(x)
        return self.fc(p, x, tape)


class VGGMini(Model):
    """Reduced VGG (Fig 8): two conv-conv-pool stages + fc head."""

    def __init__(self, c_in=3, img=32, width=8, n_classes=10):
        super().__init__("vgg_mini")
        w = width
        self.c1 = self.add(L.Conv2d("c1", c_in, w, 3, padding=1))
        self.c2 = self.add(L.Conv2d("c2", w, w, 3, padding=1))
        self.c3 = self.add(L.Conv2d("c3", w, 2 * w, 3, padding=1))
        self.c4 = self.add(L.Conv2d("c4", 2 * w, 2 * w, 3, padding=1))
        self.flat = 2 * w * (img // 4) * (img // 4)
        self.fc1 = self.add(L.Linear("fc1", self.flat, 64))
        self.fc2 = self.add(L.Linear("fc2", 64, n_classes))

    def forward(self, p, x, tape):
        x = jax.nn.relu(self.c1(p, x, tape))
        x = jax.nn.relu(self.c2(p, x, tape))
        x = L.max_pool_2x2(x)
        x = jax.nn.relu(self.c3(p, x, tape))
        x = jax.nn.relu(self.c4(p, x, tape))
        x = L.max_pool_2x2(x)
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(self.fc1(p, x, tape))
        return self.fc2(p, x, tape)


def build_model(kind, **kw):
    """Model factory used by aot.py, tests, and the config registry."""
    builders = {
        "mlp": lambda: MLP(**kw),
        "cnn": lambda: CNN(**kw),
        "rnn": lambda: RNNModel(**kw),
        "lstm": lambda: LSTMModel(**kw),
        "transformer": lambda: Transformer(**kw),
        "resnet_mini": lambda: ResNetMini(**kw),
        "vgg_mini": lambda: VGGMini(**kw),
    }
    if kind not in builders:
        raise ValueError(f"unknown model kind {kind!r}")
    return builders[kind]()
