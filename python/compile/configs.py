"""Experiment configuration registry — the single source of truth for
which (model x dataset x batch x method) artifacts exist.

Every entry maps 1:1 to entries in artifacts/manifest.json, which the
Rust coordinator and bench harness consume. The registry is organized
around the paper's evaluation section (DESIGN.md §4 index).

Methods:
  fwd             eval pass: (params, X, y) -> (loss, correct)
  nonprivate      (params, X, y)            -> (grads..., loss)
  reweight        (params, X, y, c)         -> (grads..., loss, norms)   [the paper]
  reweight_pallas same, Pallas kernel backend
  reweight_gram   same, Gram-matrix recurrent norms (our extension)
  multiloss       (params, X, y, c)         -> (grads..., loss, norms)   [baseline]
  naive1          batch=1: (params, x, y)   -> (grads..., loss, norm)    [nxBP body]
"""

DATASETS = {
    # name: (input shape sans batch, dtype, n_classes)
    "mnist": ((1, 28, 28), "f32", 10),
    "fmnist": ((1, 28, 28), "f32", 10),
    "cifar10": ((3, 32, 32), "f32", 10),
    "imdb": ((64,), "i32", 2),  # token ids, seq len 64
    "lsun16": ((3, 16, 16), "f32", 10),
    "lsun32": ((3, 32, 32), "f32", 10),
    "lsun48": ((3, 48, 48), "f32", 10),
    "lsun64": ((3, 64, 64), "f32", 10),
}

BASE_METHODS = ["fwd", "nonprivate", "reweight", "multiloss"]


class Config:
    def __init__(self, name, model, model_kw, dataset, batch, methods,
                 tags=()):
        self.name = name
        self.model = model
        self.model_kw = dict(model_kw)
        self.dataset = dataset
        self.batch = batch
        self.methods = list(methods)
        self.tags = tuple(tags)
        if dataset not in DATASETS:
            raise ValueError(f"unknown dataset {dataset!r}")

    @property
    def input_shape(self):
        return (self.batch,) + DATASETS[self.dataset][0]

    @property
    def input_dtype(self):
        return DATASETS[self.dataset][1]

    @property
    def n_classes(self):
        return DATASETS[self.dataset][2]

    def build_model(self):
        from .models import build_model

        return build_model(self.model, **self.model_kw)


def _mlp_kw(dataset, depth):
    in_dim = 1
    for d in DATASETS[dataset][0]:
        in_dim *= d
    return {"in_dim": in_dim, "depth": depth,
            "n_classes": DATASETS[dataset][2]}


def build_registry():
    """All experiment configs, keyed by name."""
    cfgs = []

    # ---- Fig 5: five architectures, B=32 ---------------------------
    cfgs.append(Config(
        "mlp2_mnist_b32", "mlp", _mlp_kw("mnist", 2), "mnist", 32,
        BASE_METHODS + ["reweight_pallas", "reweight_direct"],
        tags=("fig5", "fig6")))
    cfgs.append(Config(
        "cnn_mnist_b32", "cnn", {"c_in": 1, "img": 28}, "mnist", 32,
        BASE_METHODS + ["reweight_pallas", "reweight_direct"],
        tags=("fig5", "fig6", "e2e")))
    cfgs.append(Config(
        "rnn_mnist_b32", "rnn", {"n_in": 28}, "mnist", 32,
        BASE_METHODS + ["reweight_gram", "reweight_direct"],
        tags=("fig5", "fig6")))
    cfgs.append(Config(
        "lstm_mnist_b32", "lstm", {"n_in": 28}, "mnist", 32,
        BASE_METHODS + ["reweight_gram", "reweight_direct"], tags=("fig5",)))
    cfgs.append(Config(
        "transformer_imdb_b32", "transformer", {}, "imdb", 32,
        BASE_METHODS + ["reweight_pallas", "reweight_gram", "reweight_direct"],
        tags=("fig5", "e2e")))

    # ---- Fig 6: batch-size sweep, MLP/CNN/RNN on MNIST -------------
    for batch in (16, 64, 128):
        cfgs.append(Config(
            f"mlp2_mnist_b{batch}", "mlp", _mlp_kw("mnist", 2), "mnist",
            batch, BASE_METHODS, tags=("fig6",)))
        cfgs.append(Config(
            f"cnn_mnist_b{batch}", "cnn", {"c_in": 1, "img": 28}, "mnist",
            batch, BASE_METHODS, tags=("fig6",)))
        cfgs.append(Config(
            f"rnn_mnist_b{batch}", "rnn", {"n_in": 28}, "mnist",
            batch, BASE_METHODS, tags=("fig6",)))
    # ---- Fig 7: MLP depth sweep, B=128, MNIST(/FMNIST) + CIFAR10 ---
    for depth in (2, 4, 6, 8):
        name = f"mlp{depth}_mnist_b128"
        if depth == 2:
            # mlp2_mnist_b128 already added for fig6; just tag it
            pass
        else:
            cfgs.append(Config(
                name, "mlp", _mlp_kw("mnist", depth), "mnist", 128,
                BASE_METHODS, tags=("fig7",)))
        cfgs.append(Config(
            f"mlp{depth}_cifar10_b128", "mlp", _mlp_kw("cifar10", depth),
            "cifar10", 128, BASE_METHODS, tags=("fig7",)))

    # ---- Fig 8: deep conv nets on LSUN-like images, small batch ----
    for img in (32, 64):
        cfgs.append(Config(
            f"resnet_mini_lsun{img}_b8", "resnet_mini",
            {"c_in": 3, "img": img}, f"lsun{img}", 8,
            BASE_METHODS, tags=("fig8",)))
        cfgs.append(Config(
            f"vgg_mini_lsun{img}_b8", "vgg_mini",
            {"c_in": 3, "img": img}, f"lsun{img}", 8,
            BASE_METHODS, tags=("fig8",)))

    # ---- Fig 9: image-size sweep for ResNetMini, B=16 --------------
    for img in (16, 32, 48, 64):
        cfgs.append(Config(
            f"resnet_mini_lsun{img}_b16", "resnet_mini",
            {"c_in": 3, "img": img}, f"lsun{img}", 16,
            BASE_METHODS, tags=("fig9",)))

    # ---- naive1 (nxBP body): one batch-1 artifact per distinct
    #      (model, dataset shape) — shared across batch sizes --------
    seen = set()
    naive = []
    for cfg in cfgs:
        key = (cfg.model, tuple(sorted(cfg.model_kw.items())), cfg.dataset)
        if key in seen or not cfg.methods:
            continue
        seen.add(key)
        naive.append(Config(
            _naive_name(cfg), cfg.model, cfg.model_kw, cfg.dataset, 1,
            ["naive1"], tags=("naive",)))
    cfgs.extend(naive)

    cfgs = [c for c in cfgs if c.methods]
    # retag mlp2_mnist_b128 for fig7
    reg = {}
    for c in cfgs:
        if c.name in reg:
            raise ValueError(f"duplicate config {c.name}")
        reg[c.name] = c
    reg["mlp2_mnist_b128"].tags = reg["mlp2_mnist_b128"].tags + ("fig7",)
    # reweight_direct (one-backward extension, §Perf) at the headline
    # batch size for the ablation bench
    reg["mlp2_mnist_b128"].methods.append("reweight_direct")
    reg["cnn_mnist_b128"].methods.append("reweight_direct")
    return reg


def _naive_name(cfg):
    base = cfg.name.rsplit("_b", 1)[0]
    return f"{base}_b1"


def naive_config_name(config_name):
    """Name of the batch-1 naive1 config backing a batched config."""
    return f"{config_name.rsplit('_b', 1)[0]}_b1"


REGISTRY = build_registry()
