"""Pallas kernel: Gram-matrix per-example gradient norms for
sequence-shared weights (recurrent layers, attention projections,
position-wise FFN) — our extension beyond the paper (DESIGN.md §6).

The paper (Alg 4) materializes G_i = sum_t dz_t (x) x_t per example and
then takes its norm: cost O(s*m*n) compute and O(m*n) memory per
example. For the *norm only* (which is all ReweightGP needs for the
first backward pass),

    ||sum_s dz_s (x) x_s||_F^2 = <dZ dZ^T, X X^T>_F

needs two s x s Gram matrices: O(s^2 (m+n)) compute, O(s^2) memory.
With s = 28 time steps and m*n = 128*128 this is ~7x less compute and
~20x less VMEM — and both Grams are MXU matmuls.

TPU mapping: grid over examples; one program holds dZ_i [s, m] and
X_i [s, n] in VMEM, runs two [s,m]x[m,s]-shaped MXU matmuls, and a VPU
elementwise-product reduction.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_norm_kernel(dz_ref, x_ref, o_ref):
    dz = dz_ref[0, :, :]  # [s, m]
    x = x_ref[0, :, :]  # [s, n]
    a = jnp.dot(dz, dz.T, preferred_element_type=dz.dtype)  # [s, s]
    b = jnp.dot(x, x.T, preferred_element_type=x.dtype)  # [s, s]
    o_ref[...] = jnp.sum(a * b)[None]


def gram_norm(dz, x, *, interpret=True):
    """||sum_s dz_{i,s} (x) x_{i,s}||_F^2 per example.

    dz: [tau, s, m], x: [tau, s, n] -> [tau]
    """
    tau, s, m = dz.shape
    _, _, n = x.shape
    return pl.pallas_call(
        _gram_norm_kernel,
        grid=(tau,),
        in_specs=[
            pl.BlockSpec((1, s, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((tau,), dz.dtype),
        interpret=interpret,
    )(dz, x)
