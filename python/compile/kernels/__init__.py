"""L1: Pallas kernels for the paper's compute hot-spot — per-example
gradient (norm) computation — plus a backend dispatcher.

Backends:
  "jnp"    — the pure-jnp reference implementations (ref.py). XLA fuses
             these well on CPU; default for benchmark artifacts.
  "pallas" — the Pallas kernels (interpret=True on CPU; same source
             compiles for TPU). Exercised by the *_pallas artifact
             variants, the kernel ablation bench, and the test suite.
"""

import jax.numpy as jnp

from . import bmm_outer, gram_norm, im2col_bmm, ref, sq_norm

VALID_BACKENDS = ("jnp", "pallas")


class KernelBackend:
    """Dispatch the per-example-gradient primitives to a backend.

    `recurrent_mode` picks how sequence-shared weight norms are
    computed: "materialize" (paper Alg 4: build G_i then norm) or
    "gram" (our Gram-matrix extension, norm without materializing).
    """

    def __init__(self, backend="jnp", recurrent_mode="materialize", interpret=True):
        if backend not in VALID_BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        if recurrent_mode not in ("materialize", "gram"):
            raise ValueError(f"unknown recurrent_mode {recurrent_mode!r}")
        self.backend = backend
        self.recurrent_mode = recurrent_mode
        self.interpret = interpret

    @property
    def use_pallas(self):
        return self.backend == "pallas"

    def outer_sq_norm(self, dz, x):
        """FC layer per-example grad norm^2 (Sec 5.1)."""
        if self.use_pallas:
            return sq_norm.outer_sq_norm(dz, x, interpret=self.interpret)
        return ref.outer_sq_norm(dz, x)

    def row_sq_norm(self, x):
        """Per-example squared norm of a [tau, n] matrix (bias grads,
        LayerNorm beta, ...)."""
        if self.use_pallas:
            return sq_norm.sq_norm(x, interpret=self.interpret)
        return ref.sq_norm(x)

    def conv_sq_norm(self, dz, x, kh, kw, stride=1):
        """Conv layer per-example grad norm^2 (Sec 5.2 / Alg 3)."""
        return im2col_bmm.conv_sq_norm(
            dz, x, kh, kw, stride,
            use_pallas=self.use_pallas, interpret=self.interpret,
        )

    def seq_sq_norm(self, dz, x):
        """Sequence-shared weight per-example grad norm^2
        (Sec 5.3/5.4/5.6: recurrent, LSTM, attention projections).

        dz: [tau, s, m], x: [tau, s, n] -> [tau]
        """
        if self.recurrent_mode == "gram":
            if self.use_pallas:
                return gram_norm.gram_norm(dz, x, interpret=self.interpret)
            return ref.gram_norm(dz, x)
        # paper-faithful: materialize G_i = sum_s dz (x) x, then norm
        if self.use_pallas:
            dzt = dz.transpose(0, 2, 1)  # [tau, m, s]
            return bmm_outer.bmm_sq_norm(dzt, x, interpret=self.interpret)
        g = ref.seq_outer_sum(dz, x)
        return jnp.sum(g * g, axis=(1, 2))
