"""Pallas kernel: paper Algorithm 2 — per-example fully-connected
gradients as one batched outer product, plus the general batched
matmul (torch.bmm analogue) used by Algorithm 3.

TPU mapping (DESIGN.md §Hardware-Adaptation): grid over examples; each
program computes one example's [m, n] gradient on the MXU as a
[m, 1] x [1, n] (resp. [m, k] x [k, n]) matmul with both operands
resident in VMEM. For the paper's layer sizes (m, n <= 784x256) a whole
per-example gradient is ~0.8 MB — far under the ~16 MB VMEM budget — so
full-layer blocks with double-buffered HBM streaming of the next
example's (dz, x) are the right schedule.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bmm_outer_kernel(dz_ref, x_ref, o_ref):
    # dz_ref: [1, m], x_ref: [1, n] -> o_ref: [1, m, n]
    dz = dz_ref[0, :]
    x = x_ref[0, :]
    o_ref[0, :, :] = dz[:, None] * x[None, :]


def bmm_outer(dz, x, *, interpret=True):
    """Per-example FC gradients (Alg 2). dz: [tau, m], x: [tau, n]
    -> [tau, m, n]."""
    tau, m = dz.shape
    _, n = x.shape
    return pl.pallas_call(
        _bmm_outer_kernel,
        grid=(tau,),
        in_specs=[
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((tau, m, n), dz.dtype),
        interpret=interpret,
    )(dz, x)


def _bmm_kernel(a_ref, b_ref, o_ref):
    # a_ref: [1, m, k], b_ref: [1, k, n] -> o_ref: [1, m, n]
    a = a_ref[0, :, :]
    b = b_ref[0, :, :]
    o_ref[0, :, :] = jnp.dot(a, b, preferred_element_type=o_ref.dtype)


def bmm(a, b, *, interpret=True):
    """Batched matmul (Alg 3 workhorse). a: [tau, m, k], b: [tau, k, n]
    -> [tau, m, n]."""
    tau, m, k = a.shape
    _, _, n = b.shape
    return pl.pallas_call(
        _bmm_kernel,
        grid=(tau,),
        in_specs=[
            pl.BlockSpec((1, m, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((tau, m, n), a.dtype),
        interpret=interpret,
    )(a, b)


def _bmm_sq_norm_kernel(a_ref, b_ref, o_ref):
    # Fused Alg 3 + norm: compute one example's gradient tile and reduce
    # it to its squared Frobenius norm without writing the gradient out.
    a = a_ref[0, :, :]
    b = b_ref[0, :, :]
    g = jnp.dot(a, b, preferred_element_type=a.dtype)
    o_ref[...] = jnp.sum(g * g)[None]


def bmm_sq_norm(a, b, *, interpret=True):
    """Fused per-example gradient + squared norm: ||a_i @ b_i||_F^2.

    This is the ReweightGP hot path for conv layers — the gradient tile
    lives only in VMEM; only the scalar norm goes back to HBM.

    a: [tau, m, k], b: [tau, k, n] -> [tau]
    """
    tau, m, k = a.shape
    _, _, n = b.shape
    return pl.pallas_call(
        _bmm_sq_norm_kernel,
        grid=(tau,),
        in_specs=[
            pl.BlockSpec((1, m, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((tau,), a.dtype),
        interpret=interpret,
    )(a, b)
