"""Pallas kernel: per-example squared L2 norms (+ the fused
fully-connected variant of paper Sec 5.1).

TPU mapping (DESIGN.md §Hardware-Adaptation): this is a row reduction.
The grid walks row blocks; each program loads a [bt, n] tile of the
input into VMEM, squares it on the VPU, and reduces along the feature
axis. The fused `outer_sq_norm` variant multiplies the two row
reductions without ever forming the [m, n] outer product — the whole
point of Goodfellow's identity.

Runs under interpret=True here (CPU PJRT cannot execute Mosaic
custom-calls); the same code path compiles for real TPUs.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sq_norm_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = jnp.sum(x * x, axis=-1)


def sq_norm(x, *, block_rows=None, interpret=True):
    """Per-example squared norm. x: [tau, n] -> [tau]."""
    tau, n = x.shape
    bt = _pick_block(tau, block_rows)
    return pl.pallas_call(
        _sq_norm_kernel,
        grid=(tau // bt,),
        in_specs=[pl.BlockSpec((bt, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((tau,), x.dtype),
        interpret=interpret,
    )(x)


def _outer_sq_norm_kernel(dz_ref, x_ref, o_ref):
    dz = dz_ref[...]
    x = x_ref[...]
    o_ref[...] = jnp.sum(dz * dz, axis=-1) * jnp.sum(x * x, axis=-1)


def outer_sq_norm(dz, x, *, block_rows=None, interpret=True):
    """Fused FC per-example gradient norm (Sec 5.1):
    ||dz_i||^2 * ||x_i||^2 without materializing dz_i (x) x_i.

    dz: [tau, m], x: [tau, n] -> [tau]
    """
    tau, m = dz.shape
    _, n = x.shape
    bt = _pick_block(tau, block_rows)
    return pl.pallas_call(
        _outer_sq_norm_kernel,
        grid=(tau // bt,),
        in_specs=[
            pl.BlockSpec((bt, m), lambda i: (i, 0)),
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((tau,), dz.dtype),
        interpret=interpret,
    )(dz, x)


def _pick_block(tau, block_rows):
    """Largest divisor of tau not exceeding the requested block size.

    Row blocks keep the VMEM tile bounded while letting one grid step
    cover several examples; tau in this codebase is small (<=128) so the
    search is trivial.
    """
    if block_rows is None:
        block_rows = min(tau, 32)
    bt = min(block_rows, tau)
    while tau % bt != 0:
        bt -= 1
    return bt
