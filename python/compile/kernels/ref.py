"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has a mathematically identical jnp
implementation here. These are

  1. the correctness oracle for pytest/hypothesis (kernel vs ref), and
  2. the "jnp backend" used by default in the AOT benchmark artifacts
     (XLA fuses these well on CPU; the Pallas path is the TPU story and
     is exercised by the `*_pallas` artifact variants and the kernel
     ablation bench).

Shapes follow the paper's notation: tau = minibatch size, m = layer
output width, n = layer input width, s/T = sequence length / time steps.
"""

import jax.numpy as jnp


def sq_norm(x):
    """Per-example squared L2 norm.

    x: [tau, n]  ->  [tau]
    """
    return jnp.sum(x * x, axis=-1)


def outer_sq_norm(dz, x):
    """Goodfellow's fully-connected identity (paper Sec 5.1):

        || dL/dz_i (x) x_i ||_F^2  =  ||dL/dz_i||^2 * ||x_i||^2

    dz: [tau, m], x: [tau, n]  ->  [tau]
    """
    return sq_norm(dz) * sq_norm(x)


def bmm_outer(dz, x):
    """Per-example gradient of a fully-connected layer (paper Alg 2):
    batched outer product.

    dz: [tau, m], x: [tau, n]  ->  [tau, m, n]
    """
    return jnp.einsum("tm,tn->tmn", dz, x)


def bmm(a, b):
    """Batched matrix-matrix multiplication (torch.bmm analogue), the
    workhorse of paper Alg 3 (conv per-example grads on im2col patches)
    and of materialized sequence-summed outer products.

    a: [tau, m, k], b: [tau, k, n]  ->  [tau, m, n]
    """
    return jnp.einsum("tmk,tkn->tmn", a, b)


def seq_outer_sum(dz, x):
    """Materialized per-example gradient of a weight shared across a
    sequence/time dimension (recurrent layers Sec 5.3/5.4, attention
    projections Sec 5.6, position-wise FFN):

        G_i = sum_s dz_{i,s} (x) x_{i,s}

    dz: [tau, s, m], x: [tau, s, n]  ->  [tau, m, n]
    """
    return jnp.einsum("tsm,tsn->tmn", dz, x)


def gram_norm(dz, x):
    """Squared norm of the sequence-summed outer product WITHOUT
    materializing it (our Gram-matrix extension; see DESIGN.md §6):

        ||sum_s dz_s (x) x_s||_F^2
            = sum_{s,s'} (dz_s . dz_{s'}) (x_s . x_{s'})
            = <dZ dZ^T, X X^T>_F

    Cost tau*s^2*(m+n) instead of tau*s*m*n + tau*m*n; wins when
    s^2 << m*n.

    dz: [tau, s, m], x: [tau, s, n]  ->  [tau]
    """
    a = jnp.einsum("tsm,tum->tsu", dz, dz)
    b = jnp.einsum("tsn,tun->tsu", x, x)
    return jnp.einsum("tsu,tsu->t", a, b)
