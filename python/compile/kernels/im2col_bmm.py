"""Paper Algorithm 3: per-example convolution gradients via im2col +
batched matmul.

The paper converts the "convolve dL/dZ with the input image" form of
the conv gradient (Sec 5.2, Eq 8) into a single GEMM by flattening the
input into its im2col patch matrix — one bmm per minibatch instead of a
per-example loop, which is exactly what keeps the GPU (here: MXU) busy.

  P  = im2col(X)                       [tau, L, K]   L=(dH+1)(dW+1), K=k*k*c_in
  dZ = reshape(dL/dZ)                  [tau, c_out, L]
  G  = bmm(dZ, P)                      [tau, c_out, K] -> [tau, c_out, c_in, k, k]

The im2col itself is expressed with lax.conv_general_dilated_patches
(pure data movement — XLA lowers it to gathers/reshapes; on TPU the
Pallas bmm kernel would instead generate patches per-tile in VMEM, see
DESIGN.md §Hardware-Adaptation). The bmm is the Pallas kernel from
bmm_outer.py when the pallas backend is selected.
"""

import jax
import jax.numpy as jnp
from jax import lax

from . import bmm_outer


def im2col(x, kh, kw, stride=1):
    """Patch matrix of an NCHW image batch.

    x: [tau, c_in, H, W] -> [tau, L, K] with K = c_in*kh*kw and
    L = out_h*out_w, matching the weight layout [c_out, c_in, kh, kw]
    flattened to [c_out, K].
    """
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    # patches: [tau, K, out_h, out_w] with K ordered as (c_in, kh, kw) —
    # the same ordering as flattening W[c_out, c_in, kh, kw].
    tau, K = patches.shape[0], patches.shape[1]
    return jnp.transpose(patches.reshape(tau, K, -1), (0, 2, 1))


def conv_grads(dz, x, kh, kw, stride=1, *, use_pallas=False, interpret=True):
    """Materialized per-example conv gradients (Alg 3).

    dz: [tau, c_out, out_h, out_w] gradient w.r.t. pre-activation
    x:  [tau, c_in, H, W] layer input
    -> [tau, c_out, c_in, kh, kw]
    """
    tau, c_out = dz.shape[0], dz.shape[1]
    c_in = x.shape[1]
    p = im2col(x, kh, kw, stride)  # [tau, L, K]
    dzr = dz.reshape(tau, c_out, -1)  # [tau, c_out, L]
    if use_pallas:
        g = bmm_outer.bmm(dzr, p, interpret=interpret)
    else:
        g = jnp.einsum("tol,tlk->tok", dzr, p)
    return g.reshape(tau, c_out, c_in, kh, kw)


def conv_sq_norm(dz, x, kh, kw, stride=1, *, use_pallas=False, interpret=True):
    """Per-example squared gradient norm of a conv layer's kernel.

    Same as ||conv_grads(...)||_F^2 per example, but the pallas backend
    fuses the GEMM with the norm reduction so the [c_out, K] gradient
    tile never leaves VMEM.
    """
    tau, c_out = dz.shape[0], dz.shape[1]
    p = im2col(x, kh, kw, stride)  # [tau, L, K]
    dzr = dz.reshape(tau, c_out, -1)  # [tau, c_out, L]
    if use_pallas:
        return bmm_outer.bmm_sq_norm(dzr, p, interpret=interpret)
    g = jnp.einsum("tol,tlk->tok", dzr, p)
    return jnp.sum(g * g, axis=(1, 2))
