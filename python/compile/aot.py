"""AOT compiler: lower every (config x method) step function to HLO
text + write artifacts/manifest.json for the Rust coordinator.

Interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Incremental: each artifact records a hash of (compiler sources, config
identity); unchanged entries are skipped. Parallel: configs are lowered
in a process pool (tracing is single-threaded CPU work).

Usage:  cd python && python -m compile.aot --out ../artifacts
        [--configs name1,name2] [--jobs N] [--force]
"""

import argparse
import hashlib
import json
import multiprocessing as mp
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import baselines, clipping
from .configs import REGISTRY
from .kernels import KernelBackend

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, _DTYPES[dtype])


def make_step_fn(cfg, method):
    """Build the flat-argument step function for one (config, method).

    Argument order: params..., X, y [, c]. All outputs are flattened:
    grads..., then scalars/vectors per the manifest's `outputs` field.
    """
    model = cfg.build_model()
    n = len(model.param_specs())

    if method == "fwd":
        def step(*args):
            params, x, y = list(args[:n]), args[n], args[n + 1]
            loss, correct = model.eval_metrics(params, x, y)
            return (loss, correct)
        extra_args, outputs = [], ["loss", "correct"]

    elif method == "nonprivate":
        def step(*args):
            params, x, y = list(args[:n]), args[n], args[n + 1]
            grads, loss = baselines.nonprivate_step(model, params, x, y)
            return tuple(grads) + (loss,)
        extra_args, outputs = [], ["grads", "loss"]

    elif method in (
        "reweight", "reweight_pallas", "reweight_gram", "reweight_direct"
    ):
        kb = {
            "reweight": KernelBackend("jnp"),
            "reweight_pallas": KernelBackend("pallas"),
            "reweight_gram": KernelBackend("jnp", recurrent_mode="gram"),
            "reweight_direct": KernelBackend("jnp"),
        }[method]
        step_fn = (
            clipping.reweight_direct_step
            if method == "reweight_direct"
            else clipping.reweight_step
        )

        def step(*args):
            params, x, y, c = list(args[:n]), args[n], args[n + 1], args[n + 2]
            grads, loss, norms = step_fn(model, params, x, y, c, kb)
            return tuple(grads) + (loss, norms)
        extra_args, outputs = ["clip"], ["grads", "loss", "norms"]

    elif method == "multiloss":
        def step(*args):
            params, x, y, c = list(args[:n]), args[n], args[n + 1], args[n + 2]
            grads, loss, norms = baselines.multiloss_step(
                model, params, x, y, c)
            return tuple(grads) + (loss, norms)
        extra_args, outputs = ["clip"], ["grads", "loss", "norms"]

    elif method == "naive1":
        def step(*args):
            params, x, y = list(args[:n]), args[n], args[n + 1]
            grads, loss, norm = baselines.naive1_step(model, params, x, y)
            return tuple(grads) + (loss, norm)
        extra_args, outputs = [], ["grads", "loss", "norm"]

    else:
        raise ValueError(f"unknown method {method!r}")

    return step, extra_args, outputs


def arg_specs(cfg, method, extra_args):
    model = cfg.build_model()
    specs = [_spec(s.shape, "f32") for s in model.param_specs()]
    specs.append(_spec(cfg.input_shape, cfg.input_dtype))
    specs.append(_spec((cfg.batch,), "i32"))
    for name in extra_args:
        assert name == "clip"
        specs.append(_spec((), "f32"))
    return specs


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so
    the Rust side always unwraps one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(cfg_name, method, out_dir):
    """Lower one artifact; returns its manifest entry."""
    cfg = REGISTRY[cfg_name]
    step, extra_args, outputs = make_step_fn(cfg, method)
    specs = arg_specs(cfg, method, extra_args)
    lowered = jax.jit(step).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"{cfg_name}.{method}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    return {
        "file": fname,
        "extra_args": extra_args,
        "outputs": outputs,
        "hlo_bytes": len(text),
    }


def _source_hash():
    """Hash of the compiler package sources — artifact invalidation key."""
    h = hashlib.sha256()
    pkg = os.path.dirname(os.path.abspath(__file__))
    for root, _dirs, files in os.walk(pkg):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def _worker(task):
    cfg_name, method, out_dir = task
    try:
        entry = lower_one(cfg_name, method, out_dir)
        return (cfg_name, method, entry, None)
    except Exception as e:  # surface, don't hang the pool
        return (cfg_name, method, None, f"{type(e).__name__}: {e}")


def activation_elems_per_example(cfg):
    """Total pre-activation (tap) elements per example — the activation
    footprint the memory model (rust coordinator/memory.rs) uses for
    the paper's Sec 6.7 experiment."""
    from .layers import Tape

    model = cfg.build_model()
    tape = Tape(Tape.SHAPE)
    params = [_spec(s.shape, "f32") for s in model.param_specs()]
    x = _spec(cfg.input_shape, cfg.input_dtype)
    y = _spec((cfg.batch,), "i32")
    jax.eval_shape(
        lambda p, xx, yy: model.loss_sum(p, xx, yy, tape), params, x, y
    )
    total = 0
    for _key, shape, _dtype in tape.tap_specs:
        n = 1
        for d in shape:
            n *= d
        total += n
    return total // cfg.batch


def config_manifest_entry(cfg):
    model = cfg.build_model()
    return {
        "act_elems_per_example": activation_elems_per_example(cfg),
        "model": cfg.model,
        "model_kw": cfg.model_kw,
        "dataset": cfg.dataset,
        "batch": cfg.batch,
        "tags": list(cfg.tags),
        "n_classes": cfg.n_classes,
        "input": {"shape": list(cfg.input_shape), "dtype": cfg.input_dtype},
        "label": {"shape": [cfg.batch], "dtype": "i32"},
        "params": [
            {"name": s.name, "shape": list(s.shape)}
            for s in model.param_specs()
        ],
        "artifacts": {},
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="",
                    help="comma-separated subset of config names")
    ap.add_argument("--jobs", type=int, default=max(1, (os.cpu_count() or 2) - 1))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    src_hash = _source_hash()

    names = (
        [n.strip() for n in args.configs.split(",") if n.strip()]
        if args.configs else sorted(REGISTRY)
    )

    manifest_path = os.path.join(out_dir, "manifest.json")
    old = {}
    if os.path.exists(manifest_path) and not args.force:
        with open(manifest_path) as f:
            old = json.load(f)

    manifest = {"version": 1, "source_hash": src_hash, "configs": {}}
    tasks = []
    reused = 0
    for name in names:
        cfg = REGISTRY[name]
        entry = config_manifest_entry(cfg)
        manifest["configs"][name] = entry
        for method in cfg.methods:
            prev = old.get("configs", {}).get(name, {})
            prev_art = prev.get("artifacts", {}).get(method)
            fname = f"{name}.{method}.hlo.txt"
            if (
                not args.force
                and old.get("source_hash") == src_hash
                and prev_art
                and os.path.exists(os.path.join(out_dir, fname))
            ):
                entry["artifacts"][method] = prev_art
                reused += 1
            else:
                tasks.append((name, method, out_dir))

    print(f"[aot] {len(tasks)} artifacts to lower "
          f"({reused} up-to-date), jobs={args.jobs}", flush=True)

    failures = []
    if tasks:
        if args.jobs > 1:
            ctx = mp.get_context("spawn")
            with ctx.Pool(args.jobs) as pool:
                results = pool.map(_worker, tasks)
        else:
            results = [_worker(t) for t in tasks]
        for cfg_name, method, entry, err in results:
            if err:
                failures.append((cfg_name, method, err))
                print(f"[aot] FAIL {cfg_name}.{method}: {err}", flush=True)
            else:
                manifest["configs"][cfg_name]["artifacts"][method] = entry
                print(f"[aot] ok   {cfg_name}.{method} "
                      f"({entry['hlo_bytes'] // 1024} KiB)", flush=True)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] manifest: {manifest_path} "
          f"({len(manifest['configs'])} configs)")
    if failures:
        print(f"[aot] {len(failures)} FAILURES")
        sys.exit(1)


if __name__ == "__main__":
    main()
