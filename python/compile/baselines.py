"""Baseline gradient-clipping strategies from the paper's evaluation
(Sec 6.1): Non-private, nxBP, and multiLoss.

All DP strategies produce the *same* clipped summed gradient as
ReweightGP (the accuracy comparison is "irrelevant" per Sec 6.1) —
only the computational structure differs, which is what the benchmark
harness measures.
"""

import jax
import jax.numpy as jnp


def nonprivate_step(model, params, x, y):
    """Standard mini-batch SGD gradient (Sec 3.1).

    Returns (grads..., mean loss).
    """
    def mean_loss(p):
        per_ex = model.loss_per_example(p, x, y)
        return jnp.mean(per_ex), jnp.mean(per_ex)

    grads, loss = jax.grad(mean_loss, has_aux=True)(params)
    return grads, loss


def multiloss_step(model, params, x, y, c):
    """The multiLoss baseline (Sec 3.3 / 6.1): ask the
    auto-differentiator for all per-example gradients at once
    (vmap(grad) — the JAX analogue of torch.autograd.grad on a loss
    vector), materialize them, clip, and average.

    Returns (grads..., mean loss, per-example grad norms).
    """
    def loss_one(p, xi, yi):
        return model.loss_per_example(p, xi[None], jnp.atleast_1d(yi))[0]

    per_ex_grads = jax.vmap(
        lambda xi, yi: jax.grad(loss_one)(params, xi, yi)
    )(x, y)  # list of [tau, *param_shape] — materialized!

    sq = jnp.zeros(x.shape[0], jnp.float32)
    for g in jax.tree_util.tree_leaves(per_ex_grads):
        sq = sq + jnp.sum(g.reshape(g.shape[0], -1) ** 2, axis=-1)
    norms = jnp.sqrt(jnp.maximum(sq, 1e-24))
    nu = jnp.minimum(1.0, c / norms)

    tau = x.shape[0]
    grads = [
        jnp.einsum("t,t...->...", nu, g) / tau
        for g in per_ex_grads
    ]
    loss = jnp.mean(model.loss_per_example(params, x, y))
    return grads, loss, norms


def naive1_step(model, params, x, y):
    """One iteration of the nxBP inner loop (Sec 3.3): the gradient of
    a SINGLE example, unclipped, plus its norm. The Rust coordinator
    loops this executable over the minibatch, clips each result, and
    accumulates — reproducing TF-Privacy's naive strategy faithfully
    (backprop runs once per example).

    x: [1, ...], y: [1]. Returns (grads..., loss, norm).
    """
    def loss_one(p):
        l = model.loss_per_example(p, x, y)[0]
        return l, l

    grads, loss = jax.grad(loss_one, has_aux=True)(params)
    sq = sum(jnp.sum(g * g) for g in grads)
    norm = jnp.sqrt(jnp.maximum(sq, 1e-24))
    return grads, loss, norm
