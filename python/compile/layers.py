"""L2 layer framework with pre-activation taps.

The paper's ReweightGP needs two things from the forward/backward pass
(Sec 5, Alg 1):

  Γ — each layer's pre-activation Z, so that dL/dZ can be requested
      from the auto-differentiator, and
  Λ — each layer's input X.

PyTorch exposes these via autograd hooks. JAX has no hooks, so we use
an equivalent-by-linearity trick (DESIGN.md §5): every pre-activation
is *tapped* with an additive zero input, `z + tap`, and the per-example
gradient machinery differentiates the summed loss w.r.t. the taps —
which is exactly dL/dZ. Layer inputs are recorded on a tape alongside
the tap keys they pair with.

A `Tape` runs in one of three modes:
  shape — first pass, records tap shapes only (via jax.eval_shape);
  grad  — taps are consumed from a dict and layer inputs are recorded;
  off   — plain forward (taps are identity, nothing is recorded), used
          for the second (reweighted) backward pass and for eval.

Record kinds consumed by clipping.py:
  linear     (dz [t,m];      x [t,n])             Sec 5.1 / Alg 2
  linear_seq (dz [t,s,m];    x [t,s,n])           Sec 5.3/5.4/5.6
  conv       (dz [t,co,oh,ow]; x [t,ci,H,W], kh, kw, stride) Sec 5.2 / Alg 3
  layernorm  (dh [t,(s,)k];  hbar same shape)     Sec 5.5 / Alg 5
"""

import math

import jax
import jax.numpy as jnp
from jax import lax


class Tape:
    """Collects pre-activation taps and per-layer records."""

    SHAPE, GRAD, OFF = "shape", "grad", "off"

    def __init__(self, mode=OFF, taps=None):
        assert mode in (self.SHAPE, self.GRAD, self.OFF)
        self.mode = mode
        self.tap_specs = []  # [(key, shape, dtype)] in tap order (shape mode)
        self.taps = taps or {}  # key -> zero array (grad mode)
        self.records = []  # [(kind, aux_dict, tap_keys)]
        self._used = set()

    @classmethod
    def off(cls):
        return cls(cls.OFF)

    def tap(self, z, key):
        """Register pre-activation `z` under `key`; in grad mode adds
        the zero tap so d(loss)/d(tap) == dL/dZ."""
        if self.mode == self.SHAPE:
            self.tap_specs.append((key, z.shape, z.dtype))
            return z
        if self.mode == self.GRAD:
            if key in self._used:
                raise ValueError(f"duplicate tap key {key!r}")
            self._used.add(key)
            return z + self.taps[key]
        return z

    def record(self, kind, aux, tap_keys):
        if self.mode == self.GRAD:
            self.records.append((kind, aux, tap_keys))

    @property
    def active(self):
        return self.mode != self.OFF


class ParamSpec:
    """Name + shape + initializer of one parameter tensor."""

    def __init__(self, name, shape, init):
        self.name = name
        self.shape = tuple(shape)
        self.init = init  # fn(key, shape) -> array

    def __repr__(self):
        return f"ParamSpec({self.name}, {self.shape})"


def glorot(key, shape):
    """Glorot/Xavier uniform — fan sizes from the trailing two dims
    (or all-but-first for conv kernels)."""
    if len(shape) == 2:
        fan_in, fan_out = shape[0], shape[1]
    elif len(shape) == 4:  # [c_out, c_in, kh, kw]
        rf = shape[2] * shape[3]
        fan_in, fan_out = shape[1] * rf, shape[0] * rf
    else:
        fan_in = fan_out = int(math.sqrt(max(1, math.prod(shape))))
    limit = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -limit, limit)


def zeros_init(_key, shape):
    return jnp.zeros(shape, jnp.float32)


def ones_init(_key, shape):
    return jnp.ones(shape, jnp.float32)


class Layer:
    """Base class: parameters + tape-aware application."""

    def __init__(self, name):
        self.name = name

    def param_specs(self):
        return []

    def __call__(self, p, x, tape):
        raise NotImplementedError


class Linear(Layer):
    """Fully-connected layer, z = x W + b (paper Sec 5.1).

    Accepts [tau, n] input or [tau, s, n] sequence input (position-wise
    application — attention projections and transformer FFN, Sec 5.6).
    """

    def __init__(self, name, n_in, n_out, bias=True):
        super().__init__(name)
        self.n_in, self.n_out, self.bias = n_in, n_out, bias

    def param_specs(self):
        specs = [ParamSpec(f"{self.name}.w", (self.n_in, self.n_out), glorot)]
        if self.bias:
            specs.append(ParamSpec(f"{self.name}.b", (self.n_out,), zeros_init))
        return specs

    def __call__(self, p, x, tape):
        z = x @ p[f"{self.name}.w"]
        if self.bias:
            z = z + p[f"{self.name}.b"]
        key = f"{self.name}.z"
        z = tape.tap(z, key)
        kind = "linear" if x.ndim == 2 else "linear_seq"
        tape.record(kind, {"x": x, "bias": self.bias, "name": self.name}, [key])
        return z


class Conv2d(Layer):
    """2D convolution, NCHW, square kernel (paper Sec 5.2 / Alg 3).

    `padding` pixels of zero padding are applied explicitly so the
    per-example-gradient rule sees the padded input (im2col over the
    padded image is exactly the paper's P matrix).
    """

    def __init__(self, name, c_in, c_out, kernel, stride=1, padding=0, bias=True):
        super().__init__(name)
        self.c_in, self.c_out = c_in, c_out
        self.kernel, self.stride, self.padding = kernel, stride, padding
        self.bias = bias

    def param_specs(self):
        k = self.kernel
        specs = [ParamSpec(f"{self.name}.w", (self.c_out, self.c_in, k, k), glorot)]
        if self.bias:
            specs.append(ParamSpec(f"{self.name}.b", (self.c_out,), zeros_init))
        return specs

    def __call__(self, p, x, tape):
        if self.padding:
            pad = self.padding
            x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
        w = p[f"{self.name}.w"]
        z = lax.conv_general_dilated(
            x, w,
            window_strides=(self.stride, self.stride),
            padding="VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )
        if self.bias:
            z = z + p[f"{self.name}.b"][None, :, None, None]
        key = f"{self.name}.z"
        z = tape.tap(z, key)
        tape.record(
            "conv",
            {"x": x, "kh": self.kernel, "kw": self.kernel,
             "stride": self.stride, "bias": self.bias, "name": self.name},
            [key],
        )
        return z


class LayerNorm(Layer):
    """Layer normalization over the trailing feature axis (Sec 5.5).

    Output h = gamma * hbar + beta is treated as the pre-activation;
    the rule combines dL/dh with the recorded normalized input hbar.
    """

    def __init__(self, name, dim, eps=1e-5):
        super().__init__(name)
        self.dim, self.eps = dim, eps

    def param_specs(self):
        return [
            ParamSpec(f"{self.name}.gamma", (self.dim,), ones_init),
            ParamSpec(f"{self.name}.beta", (self.dim,), zeros_init),
        ]

    def __call__(self, p, x, tape):
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
        hbar = (x - mu) / jnp.sqrt(var + self.eps)
        h = p[f"{self.name}.gamma"] * hbar + p[f"{self.name}.beta"]
        key = f"{self.name}.h"
        h = tape.tap(h, key)
        tape.record("layernorm", {"hbar": hbar, "name": self.name}, [key])
        return h


class RNN(Layer):
    """Vanilla recurrent layer, unrolled (paper Sec 5.3 / Alg 4).

        z_t = h_{t-1} W + x_t V + b,   h_t = phi(z_t)

    Returns the final hidden state. One tap per time step; the record
    stacks hidden states / inputs along a time axis for the
    sequence-summed outer-product rule (Eq 12).
    """

    def __init__(self, name, n_in, n_hidden, activation=jnp.tanh):
        super().__init__(name)
        self.n_in, self.n_hidden = n_in, n_hidden
        self.activation = activation

    def param_specs(self):
        return [
            ParamSpec(f"{self.name}.w", (self.n_hidden, self.n_hidden), glorot),
            ParamSpec(f"{self.name}.v", (self.n_in, self.n_hidden), glorot),
            ParamSpec(f"{self.name}.b", (self.n_hidden,), zeros_init),
        ]

    def __call__(self, p, x, tape):
        # x: [tau, T, n_in]
        tau, T, _ = x.shape
        w, v, b = p[f"{self.name}.w"], p[f"{self.name}.v"], p[f"{self.name}.b"]
        h = jnp.zeros((tau, self.n_hidden), x.dtype)
        hs, keys = [], []
        for t in range(T):
            hs.append(h)
            z = h @ w + x[:, t, :] @ v + b
            key = f"{self.name}.z{t}"
            z = tape.tap(z, key)
            keys.append(key)
            h = self.activation(z)
        tape.record(
            "recurrent",
            {"h": jnp.stack(hs, axis=1), "x": x, "bias": True,
             "name": self.name},
            keys,
        )
        return h


class LSTM(Layer):
    """LSTM with gate weights stacked as W in R^{m x 4m} (Sec 5.4):
    per-example gradients follow the recurrent rule on the stacked
    pre-activation z_t in R^{4m}.

    Gate order: [f, i, g, o] (paper order).
    """

    def __init__(self, name, n_in, n_hidden):
        super().__init__(name)
        self.n_in, self.n_hidden = n_in, n_hidden

    def param_specs(self):
        m = self.n_hidden
        return [
            ParamSpec(f"{self.name}.w", (m, 4 * m), glorot),
            ParamSpec(f"{self.name}.v", (self.n_in, 4 * m), glorot),
            ParamSpec(f"{self.name}.b", (4 * m,), zeros_init),
        ]

    def __call__(self, p, x, tape):
        tau, T, _ = x.shape
        m = self.n_hidden
        w, v, b = p[f"{self.name}.w"], p[f"{self.name}.v"], p[f"{self.name}.b"]
        h = jnp.zeros((tau, m), x.dtype)
        c = jnp.zeros((tau, m), x.dtype)
        hs, keys = [], []
        for t in range(T):
            hs.append(h)
            z = h @ w + x[:, t, :] @ v + b  # [tau, 4m]
            key = f"{self.name}.z{t}"
            z = tape.tap(z, key)
            keys.append(key)
            f = jax.nn.sigmoid(z[:, 0 * m:1 * m])
            i = jax.nn.sigmoid(z[:, 1 * m:2 * m])
            g = jnp.tanh(z[:, 2 * m:3 * m])
            o = jax.nn.sigmoid(z[:, 3 * m:4 * m])
            c = f * c + i * g
            h = o * jnp.tanh(c)
        tape.record(
            "recurrent",
            {"h": jnp.stack(hs, axis=1), "x": x, "bias": True,
             "name": self.name},
            keys,
        )
        return h


class Embedding(Layer):
    """Frozen embedding lookup (GloVe substitute — see DESIGN.md §5).

    The paper uses pretrained, non-trained embeddings for the
    Transformer/IMDB experiment, so this layer has no trainable
    parameters: the table is a deterministic constant derived from the
    layer name.
    """

    def __init__(self, name, vocab, dim):
        super().__init__(name)
        self.vocab, self.dim = vocab, dim
        seed = abs(hash(name)) % (2 ** 31)
        self.table = glorot(jax.random.PRNGKey(seed), (vocab, dim))

    def __call__(self, p, x, tape):
        # x: [tau, s] int32 token ids -> [tau, s, dim]
        return self.table[x]


class MultiHeadAttention(Layer):
    """Multi-head self-attention (paper Sec 5.6, Fig 4).

    The four projection weights W^Q, W^K, W^V, W^O are position-wise
    linear layers; their per-example gradients are the sequence-summed
    outer products the paper derives ((dL/dQ)^T Q etc.), which is the
    `linear_seq` record emitted by the Linear sublayers.
    """

    def __init__(self, name, d_model, n_heads):
        super().__init__(name)
        assert d_model % n_heads == 0
        self.d_model, self.n_heads = d_model, n_heads
        self.d_k = d_model // n_heads
        self.wq = Linear(f"{name}.wq", d_model, d_model, bias=False)
        self.wk = Linear(f"{name}.wk", d_model, d_model, bias=False)
        self.wv = Linear(f"{name}.wv", d_model, d_model, bias=False)
        self.wo = Linear(f"{name}.wo", d_model, d_model, bias=False)

    def param_specs(self):
        return (
            self.wq.param_specs() + self.wk.param_specs()
            + self.wv.param_specs() + self.wo.param_specs()
        )

    def __call__(self, p, x, tape):
        # x: [tau, s, d_model]
        tau, s, d = x.shape
        h, dk = self.n_heads, self.d_k
        q = self.wq(p, x, tape)
        k = self.wk(p, x, tape)
        v = self.wv(p, x, tape)

        def split(a):  # [tau, s, d] -> [tau, h, s, dk]
            return a.reshape(tau, s, h, dk).transpose(0, 2, 1, 3)

        qh, kh, vh = split(q), split(k), split(v)
        att = jnp.einsum("thsd,thud->thsu", qh, kh) / math.sqrt(dk)
        att = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("thsu,thud->thsd", att, vh)
        out = out.transpose(0, 2, 1, 3).reshape(tau, s, d)
        return self.wo(p, out, tape)


def positional_encoding(s, d):
    """Sinusoidal positional encoding [s, d] (Vaswani et al.)."""
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, 2.0 * i / d)
    pe = jnp.zeros((s, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


def max_pool_2x2(x):
    """2x2 max pooling with stride 2, NCHW (parameterless — Sec 5.7)."""
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def avg_pool_global(x):
    """Global average pooling NCHW -> [tau, c]."""
    return jnp.mean(x, axis=(2, 3))


def cross_entropy_per_example(logits, y):
    """Per-example cross-entropy loss. logits [tau, C], y [tau] int32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]


def accuracy_count(logits, y):
    """Number of correct predictions (f32 scalar for a uniform ABI)."""
    return jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
