"""ReweightGP — the paper's contribution (Sec 5, Alg 1).

Per-example gradient *clipping* without per-example gradient
*materialization*:

  1. First backward pass: differentiate the summed loss w.r.t. the
     pre-activation taps (exactly dL/dZ per layer). Combine each dZ
     with the recorded layer input using the layer-type rule
     (Secs 5.1-5.6) to get every example's squared gradient norm.
  2. Weights nu_i = min(1, c / ||grad_i||)  (Eq 2).
  3. Second backward pass over the reweighted mean loss
     1/tau sum_i nu_i l_i  (Eq 3) — an ordinary batched backward whose
     gradient equals 1/tau sum_i clip_c(grad_i) exactly.

The returned gradient is ready for the Gaussian mechanism: the Rust
coordinator adds N(0, sigma^2 c^2 / tau^2) noise and feeds DP-Adam.
"""

import jax
import jax.numpy as jnp

from .kernels import KernelBackend
from .layers import Tape


def _rule_linear(kb, dzs, aux):
    """Sec 5.1 (Goodfellow): ||dz (x) x||^2 = ||dz||^2 ||x||^2."""
    (dz,) = dzs
    sq = kb.outer_sq_norm(dz, aux["x"])
    if aux["bias"]:
        sq = sq + kb.row_sq_norm(dz)
    return sq


def _rule_linear_seq(kb, dzs, aux):
    """Sec 5.6 / position-wise shared weights: the per-example gradient
    is the sequence-summed outer product sum_s dz_s (x) x_s."""
    (dz,) = dzs  # [tau, s, m]
    sq = kb.seq_sq_norm(dz, aux["x"])
    if aux["bias"]:
        sq = sq + kb.row_sq_norm(jnp.sum(dz, axis=1))
    return sq


def _rule_conv(kb, dzs, aux):
    """Sec 5.2 / Alg 3: im2col + batched GEMM."""
    (dz,) = dzs  # [tau, c_out, oh, ow]
    sq = kb.conv_sq_norm(dz, aux["x"], aux["kh"], aux["kw"], aux["stride"])
    if aux["bias"]:
        # grad_b per example = sum over spatial positions of dz
        sq = sq + kb.row_sq_norm(jnp.sum(dz, axis=(2, 3)))
    return sq


def _rule_layernorm(kb, dzs, aux):
    """Sec 5.5 / Alg 5: grad_gamma = dH (.) hbar, grad_beta = dH
    (summed over any sequence axes)."""
    (dh,) = dzs
    hbar = aux["hbar"]
    if dh.ndim == 2:
        g_gamma = dh * hbar
        g_beta = dh
    else:  # [tau, s, k] -> sum over s
        g_gamma = jnp.einsum("tsk,tsk->tk", dh, hbar)
        g_beta = jnp.sum(dh, axis=1)
    return kb.row_sq_norm(g_gamma) + kb.row_sq_norm(g_beta)


def _rule_recurrent(kb, dzs, aux):
    """Secs 5.3/5.4 (Eq 12): grad_W = sum_t dz_t (x) h_{t-1},
    grad_V = sum_t dz_t (x) x_t, grad_b = sum_t dz_t."""
    dz = jnp.stack(dzs, axis=1)  # [tau, T, m]
    sq = kb.seq_sq_norm(dz, aux["h"]) + kb.seq_sq_norm(dz, aux["x"])
    if aux["bias"]:
        sq = sq + kb.row_sq_norm(jnp.sum(dz, axis=1))
    return sq


_RULES = {
    "linear": _rule_linear,
    "linear_seq": _rule_linear_seq,
    "conv": _rule_conv,
    "layernorm": _rule_layernorm,
    "recurrent": _rule_recurrent,
}


def per_example_sq_norms(model, params, x, y, kb=None):
    """||grad_theta l(y_i, M(x_i))||^2 for every example in the batch,
    computed from (dL/dZ, layer inputs) only — no per-example gradient
    is ever materialized (except tile-local inside kernels).
    """
    kb = kb or KernelBackend()

    # Pass 1 (shape): discover tap keys/shapes without computing.
    shape_tape = Tape(Tape.SHAPE)
    jax.eval_shape(lambda p: model.loss_sum(p, x, y, shape_tape), params)

    taps = {
        key: jnp.zeros(shape, dtype)
        for key, shape, dtype in shape_tape.tap_specs
    }

    # Pass 2 (grad): dL/dZ for every tap. Summed (not mean) loss makes
    # row i of each dZ equal d l_i / d z_i directly.
    grad_tape = Tape(Tape.GRAD, taps)

    def tapped_loss(taps):
        grad_tape.records.clear()
        grad_tape._used.clear()
        grad_tape.taps = taps
        loss = model.loss_sum(params, x, y, grad_tape)
        return loss, list(grad_tape.records)

    dz_by_key, records = jax.grad(tapped_loss, has_aux=True)(taps)

    sq = jnp.zeros(x.shape[0], jnp.float32)
    for kind, aux, tap_keys in records:
        dzs = [dz_by_key[k] for k in tap_keys]
        sq = sq + _RULES[kind](kb, dzs, aux)
    return sq


def clip_weights(sq_norms, c):
    """nu_i = min(1, c / ||grad_i||)  (Eq 2)."""
    norms = jnp.sqrt(jnp.maximum(sq_norms, 1e-24))
    return jnp.minimum(1.0, c / norms), norms


def reweight_step(model, params, x, y, c, kb=None):
    """One ReweightGP step (Alg 1 lines 4-14, noise excluded).

    Returns (grads..., mean unweighted loss, per-example grad norms).
    grads = 1/tau sum_i clip_c(grad l_i) — exactly per-example clipping.
    """
    sq = per_example_sq_norms(model, params, x, y, kb)
    nu, norms = clip_weights(sq, c)
    nu = jax.lax.stop_gradient(nu)
    tau = x.shape[0]

    def weighted_loss(p):
        per_ex = model.loss_per_example(p, x, y)
        return jnp.sum(nu * per_ex) / tau, jnp.mean(per_ex)

    grads, loss = jax.grad(weighted_loss, has_aux=True)(params)
    return grads, loss, norms


# ---------------------------------------------------------------------
# reweight_direct — our §Perf extension beyond the paper: ONE backward
# pass total. The same (dL/dZ, layer input) pairs that give the norms
# also determine every weight gradient (that is the content of the
# paper's Sec 5 derivations), so after computing nu we assemble the
# *weighted* gradient per layer directly:
#
#   linear:      dW = X^T (nu . dZ)            db = sum_i nu_i dz_i
#   linear_seq:  dW = sum_s X_s^T (nu . dZ_s)  (attention, FFN)
#   conv:        dW = sum_i nu_i dZ_i P_i      (im2col, Alg 3 aggregated)
#   recurrent:   dW = sum_t H_t^T (nu . dZ_t), dV likewise over X_t
#   layernorm:   dgamma = sum_i nu_i dH_i . hbar_i,  dbeta = sum nu dH
#
# instead of re-running forward+backward over the reweighted loss
# (Alg 1 line 14). Exactness is tested against reweight_step.
# ---------------------------------------------------------------------

def _grad_linear(nu, dzs, aux):
    (dz,) = dzs
    wdz = nu[:, None] * dz
    out = {"w": jnp.einsum("tn,tm->nm", aux["x"], wdz)}
    if aux["bias"]:
        out["b"] = jnp.sum(wdz, axis=0)
    return out


def _grad_linear_seq(nu, dzs, aux):
    (dz,) = dzs
    wdz = nu[:, None, None] * dz
    out = {"w": jnp.einsum("tsn,tsm->nm", aux["x"], wdz)}
    if aux["bias"]:
        out["b"] = jnp.sum(wdz, axis=(0, 1))
    return out


def _grad_conv(nu, dzs, aux):
    from .kernels import im2col_bmm

    (dz,) = dzs  # [tau, c_out, oh, ow]
    tau, c_out = dz.shape[0], dz.shape[1]
    c_in = aux["x"].shape[1]
    p = im2col_bmm.im2col(aux["x"], aux["kh"], aux["kw"], aux["stride"])
    dzr = (nu[:, None, None] * dz.reshape(tau, c_out, -1))
    g = jnp.einsum("tol,tlk->ok", dzr, p)
    out = {"w": g.reshape(c_out, c_in, aux["kh"], aux["kw"])}
    if aux["bias"]:
        out["b"] = jnp.einsum("t,tohw->o", nu, dz)
    return out


def _grad_layernorm(nu, dzs, aux):
    (dh,) = dzs
    hbar = aux["hbar"]
    if dh.ndim == 2:
        wdh = nu[:, None] * dh
        return {
            "gamma": jnp.sum(wdh * hbar, axis=0),
            "beta": jnp.sum(wdh, axis=0),
        }
    wdh = nu[:, None, None] * dh
    return {
        "gamma": jnp.einsum("tsk,tsk->k", wdh, hbar),
        "beta": jnp.sum(wdh, axis=(0, 1)),
    }


def _grad_recurrent(nu, dzs, aux):
    dz = jnp.stack(dzs, axis=1)  # [tau, T, m]
    wdz = nu[:, None, None] * dz
    return {
        "w": jnp.einsum("tTn,tTm->nm", aux["h"], wdz),
        "v": jnp.einsum("tTn,tTm->nm", aux["x"], wdz),
        "b": jnp.sum(wdz, axis=(0, 1)),
    }


_GRAD_RULES = {
    "linear": _grad_linear,
    "linear_seq": _grad_linear_seq,
    "conv": _grad_conv,
    "layernorm": _grad_layernorm,
    "recurrent": _grad_recurrent,
}

_PARAM_SUFFIXES = {
    "linear": {"w": ".w", "b": ".b"},
    "linear_seq": {"w": ".w", "b": ".b"},
    "conv": {"w": ".w", "b": ".b"},
    "layernorm": {"gamma": ".gamma", "beta": ".beta"},
    "recurrent": {"w": ".w", "v": ".v", "b": ".b"},
}


def reweight_direct_step(model, params, x, y, c, kb=None):
    """ReweightGP with the second backward pass eliminated: norms AND
    the weighted gradient are both assembled from one tapped backward.

    Same contract as reweight_step; tested to produce identical
    gradients.
    """
    kb = kb or KernelBackend()
    tau = x.shape[0]

    shape_tape = Tape(Tape.SHAPE)
    jax.eval_shape(lambda p: model.loss_sum(p, x, y, shape_tape), params)
    taps = {
        key: jnp.zeros(shape, dtype)
        for key, shape, dtype in shape_tape.tap_specs
    }
    grad_tape = Tape(Tape.GRAD, taps)

    def tapped_loss(taps):
        grad_tape.records.clear()
        grad_tape._used.clear()
        grad_tape.taps = taps
        loss = model.loss_sum(params, x, y, grad_tape)
        return loss, (list(grad_tape.records), loss / tau)

    dz_by_key, (records, mean_loss) = jax.grad(tapped_loss, has_aux=True)(taps)

    # pass 1 products: per-example squared norms
    sq = jnp.zeros(tau, jnp.float32)
    for kind, aux, tap_keys in records:
        dzs = [dz_by_key[k] for k in tap_keys]
        sq = sq + _RULES[kind](kb, dzs, aux)
    nu, norms = clip_weights(sq, c)
    nu = jax.lax.stop_gradient(nu) / tau  # fold the 1/tau average in

    # pass 2 replaced: weighted gradients from the same intermediates
    grad_by_name = {}
    for kind, aux, tap_keys in records:
        dzs = [dz_by_key[k] for k in tap_keys]
        layer_grads = _GRAD_RULES[kind](nu, dzs, aux)
        for part, g in layer_grads.items():
            name = aux["name"] + _PARAM_SUFFIXES[kind][part]
            # a layer applied twice (weight sharing) accumulates
            grad_by_name[name] = grad_by_name.get(name, 0.0) + g

    names = model.param_names()
    missing = [n for n in names if n not in grad_by_name]
    if missing:
        raise ValueError(f"no direct-gradient rule produced {missing}")
    grads = [grad_by_name[n] for n in names]
    return grads, mean_loss, norms
