"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, with
hypothesis sweeping shapes and dtypes. This is the CORE kernel
correctness signal (DESIGN.md §8)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    KernelBackend, bmm_outer, gram_norm, im2col_bmm, ref, sq_norm,
)

jax.config.update("jax_platform_name", "cpu")

# Each hypothesis example traces + interprets a fresh Pallas call, so
# example counts are kept modest to bound suite runtime.
SETTINGS = dict(max_examples=8, deadline=None)

dims = st.integers(min_value=1, max_value=24)
taus = st.integers(min_value=1, max_value=12)
seeds = st.integers(min_value=0, max_value=2**31 - 1)
dtypes = st.sampled_from([jnp.float32])


def rand(key, shape, dtype, scale=2.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


@given(tau=taus, n=dims, seed=seeds, dtype=dtypes)
@settings(**SETTINGS)
def test_sq_norm_matches_ref(tau, n, seed, dtype):
    (k,) = keys(seed, 1)
    x = rand(k, (tau, n), dtype)
    got = sq_norm.sq_norm(x)
    np.testing.assert_allclose(got, ref.sq_norm(x), rtol=1e-5, atol=1e-5)


@given(tau=taus, m=dims, n=dims, seed=seeds, dtype=dtypes)
@settings(**SETTINGS)
def test_outer_sq_norm_matches_ref(tau, m, n, seed, dtype):
    k1, k2 = keys(seed, 2)
    dz, x = rand(k1, (tau, m), dtype), rand(k2, (tau, n), dtype)
    got = sq_norm.outer_sq_norm(dz, x)
    np.testing.assert_allclose(
        got, ref.outer_sq_norm(dz, x), rtol=1e-4, atol=1e-4
    )


@given(tau=taus, m=dims, n=dims, seed=seeds)
@settings(**SETTINGS)
def test_bmm_outer_matches_ref(tau, m, n, seed):
    k1, k2 = keys(seed, 2)
    dz, x = rand(k1, (tau, m), jnp.float32), rand(k2, (tau, n), jnp.float32)
    got = bmm_outer.bmm_outer(dz, x)
    np.testing.assert_allclose(
        got, ref.bmm_outer(dz, x), rtol=1e-5, atol=1e-5
    )


@given(tau=taus, m=dims, k=dims, n=dims, seed=seeds)
@settings(**SETTINGS)
def test_bmm_matches_ref(tau, m, k, n, seed):
    k1, k2 = keys(seed, 2)
    a = rand(k1, (tau, m, k), jnp.float32, 1.0)
    b = rand(k2, (tau, k, n), jnp.float32, 1.0)
    got = bmm_outer.bmm(a, b)
    np.testing.assert_allclose(got, ref.bmm(a, b), rtol=1e-4, atol=1e-4)


@given(tau=taus, m=dims, k=dims, n=dims, seed=seeds)
@settings(**SETTINGS)
def test_bmm_sq_norm_fused_matches_unfused(tau, m, k, n, seed):
    k1, k2 = keys(seed, 2)
    a = rand(k1, (tau, m, k), jnp.float32, 1.0)
    b = rand(k2, (tau, k, n), jnp.float32, 1.0)
    got = bmm_outer.bmm_sq_norm(a, b)
    want = jnp.sum(ref.bmm(a, b) ** 2, axis=(1, 2))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@given(tau=taus, s=st.integers(1, 10), m=dims, n=dims, seed=seeds)
@settings(**SETTINGS)
def test_gram_norm_matches_materialized(tau, s, m, n, seed):
    k1, k2 = keys(seed, 2)
    dz = rand(k1, (tau, s, m), jnp.float32, 1.0)
    x = rand(k2, (tau, s, n), jnp.float32, 1.0)
    got = gram_norm.gram_norm(dz, x)
    want = jnp.sum(ref.seq_outer_sum(dz, x) ** 2, axis=(1, 2))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    # and the jnp gram path agrees too
    np.testing.assert_allclose(
        ref.gram_norm(dz, x), want, rtol=1e-3, atol=1e-3
    )


@given(
    tau=st.integers(1, 4),
    c_in=st.integers(1, 3),
    c_out=st.integers(1, 4),
    img=st.integers(5, 12),
    kern=st.integers(1, 5),
    seed=seeds,
)
@settings(max_examples=8, deadline=None)
def test_conv_grads_match_autodiff(tau, c_in, c_out, img, kern, seed):
    """Alg 3 against jax.grad ground truth: the im2col+bmm per-example
    conv gradient must equal the real gradient of a conv layer."""
    if kern > img:
        kern = img
    k1, k2, k3 = keys(seed, 3)
    x = rand(k1, (tau, c_in, img, img), jnp.float32, 1.0)
    w = rand(k2, (c_out, c_in, kern, kern), jnp.float32, 0.5)
    cotangent = rand(k3, (tau, c_out, img - kern + 1, img - kern + 1),
                     jnp.float32, 1.0)

    def conv_one(w, xi):
        return jax.lax.conv_general_dilated(
            xi[None], w, (1, 1), "VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"))[0]

    # ground truth: per-example VJP w.r.t. w with the given cotangent
    want = []
    for i in range(tau):
        _, vjp = jax.vjp(lambda wi: conv_one(wi, x[i]), w)
        want.append(vjp(cotangent[i])[0])
    want = jnp.stack(want)

    got = im2col_bmm.conv_grads(cotangent, x, kern, kern)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    # fused norm agrees
    got_n = im2col_bmm.conv_sq_norm(cotangent, x, kern, kern)
    want_n = jnp.sum(want ** 2, axis=(1, 2, 3, 4))
    np.testing.assert_allclose(got_n, want_n, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("stride", [1, 2, 3])
def test_conv_grads_strided(stride):
    """Strided convolution support (used by no current model config but
    part of the public kernel API)."""
    k1, k2, k3 = keys(42, 3)
    tau, c_in, c_out, img, kern = 2, 2, 3, 9, 3
    out = (img - kern) // stride + 1
    x = rand(k1, (tau, c_in, img, img), jnp.float32, 1.0)
    w = rand(k2, (c_out, c_in, kern, kern), jnp.float32, 0.5)
    cot = rand(k3, (tau, c_out, out, out), jnp.float32, 1.0)

    def conv_one(w, xi):
        return jax.lax.conv_general_dilated(
            xi[None], w, (stride, stride), "VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"))[0]

    want = []
    for i in range(tau):
        _, vjp = jax.vjp(lambda wi: conv_one(wi, x[i]), w)
        want.append(vjp(cot[i])[0])
    want = jnp.stack(want)
    got = im2col_bmm.conv_grads(cot, x, kern, kern, stride)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("block_rows", [None, 1, 3, 32])
def test_sq_norm_block_shapes(block_rows):
    """Block-size sweep: the grid decomposition must not change the
    result (this is the L1 tuning knob)."""
    x = rand(jax.random.PRNGKey(0), (12, 33), jnp.float32)
    got = sq_norm.sq_norm(x, block_rows=block_rows)
    np.testing.assert_allclose(got, ref.sq_norm(x), rtol=1e-5, atol=1e-5)


def test_backend_dispatcher_validation():
    with pytest.raises(ValueError):
        KernelBackend("cuda")
    with pytest.raises(ValueError):
        KernelBackend("jnp", recurrent_mode="nope")
    kb = KernelBackend("pallas", recurrent_mode="gram")
    assert kb.use_pallas


def test_kernels_are_jittable():
    """Kernels must lower inside jit (the AOT requirement)."""
    x = rand(jax.random.PRNGKey(1), (4, 8), jnp.float32)
    dz = rand(jax.random.PRNGKey(2), (4, 6), jnp.float32)
    f = jax.jit(lambda a, b: sq_norm.outer_sq_norm(a, b))
    np.testing.assert_allclose(
        f(dz, x), ref.outer_sq_norm(dz, x), rtol=1e-5, atol=1e-5
    )
