"""L2 correctness: the paper's equivalence theorem.

ReweightGP (taps -> per-layer norm rules -> reweighted second backward)
must produce EXACTLY the per-example-clipped gradient that the
materializing oracle (vmap of grad, clip, average) produces — for every
architecture, every kernel backend, and both recurrent-norm modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import baselines, clipping, models
from compile.kernels import KernelBackend

jax.config.update("jax_platform_name", "cpu")

TAU = 4


def data_for(model, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    if model.name == "transformer":
        x = jax.random.randint(k1, (TAU, 64), 0, 5000)
        y = jax.random.randint(k2, (TAU,), 0, 2)
    elif model.name in ("rnn", "lstm"):
        x = jax.random.normal(k1, (TAU, 28, 28))
        y = jax.random.randint(k2, (TAU,), 0, 10)
    elif model.name.startswith("mlp"):
        x = jax.random.normal(k1, (TAU, 784))
        y = jax.random.randint(k2, (TAU,), 0, 10)
    elif model.name == "cnn":
        x = jax.random.normal(k1, (TAU, 1, 28, 28))
        y = jax.random.randint(k2, (TAU,), 0, 10)
    else:  # conv nets on 3x32x32
        x = jax.random.normal(k1, (TAU, 3, 32, 32))
        y = jax.random.randint(k2, (TAU,), 0, 10)
    return x, y


def assert_equiv(model, kb=None, c=0.5, tol=2e-5, seed=0):
    params = model.init_params(seed)
    x, y = data_for(model, seed)
    g1, l1, n1 = clipping.reweight_step(model, params, x, y, c, kb)
    g2, l2, n2 = baselines.multiloss_step(model, params, x, y, c)
    np.testing.assert_allclose(n1, n2, rtol=tol, atol=tol)
    np.testing.assert_allclose(l1, l2, rtol=tol, atol=tol)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=tol, atol=tol)


ALL_MODELS = {
    "mlp2": lambda: models.MLP(784),
    "mlp4": lambda: models.MLP(784, depth=4),
    "cnn": lambda: models.CNN(),
    "rnn": lambda: models.RNNModel(),
    "lstm": lambda: models.LSTMModel(),
    "transformer": lambda: models.Transformer(),
    "resnet_mini": lambda: models.ResNetMini(),
    "vgg_mini": lambda: models.VGGMini(),
}


@pytest.mark.parametrize("name", sorted(ALL_MODELS))
def test_reweight_equals_oracle_jnp(name):
    assert_equiv(ALL_MODELS[name]())


@pytest.mark.parametrize("name", ["mlp2", "cnn", "rnn", "transformer"])
def test_reweight_equals_oracle_pallas(name):
    assert_equiv(ALL_MODELS[name](), KernelBackend("pallas"))


@pytest.mark.parametrize("name", ["rnn", "lstm", "transformer"])
def test_reweight_equals_oracle_gram(name):
    assert_equiv(ALL_MODELS[name](), KernelBackend("jnp", recurrent_mode="gram"))


def test_reweight_equals_oracle_pallas_gram():
    assert_equiv(
        models.RNNModel(), KernelBackend("pallas", recurrent_mode="gram")
    )


@given(
    c=st.floats(min_value=0.01, max_value=20.0),
    seed=st.integers(0, 1000),
)
@settings(max_examples=10, deadline=None)
def test_equivalence_across_thresholds(c, seed):
    """Property: equivalence holds for any clip threshold, from
    clip-everything to clip-nothing."""
    assert_equiv(models.MLP(784, hidden=[16, 16]), c=c, seed=seed, tol=5e-5)


@pytest.mark.parametrize(
    "name", ["mlp2", "cnn", "rnn", "lstm", "transformer", "resnet_mini"]
)
def test_reweight_direct_equals_reweight(name):
    """Our one-backward extension (§Perf): assembling the weighted
    gradient from the tapped intermediates must equal the paper's
    two-backward ReweightGP exactly."""
    model = ALL_MODELS[name]()
    params = model.init_params(0)
    x, y = data_for(model)
    g1, l1, n1 = clipping.reweight_step(model, params, x, y, 0.5)
    g2, l2, n2 = clipping.reweight_direct_step(model, params, x, y, 0.5)
    np.testing.assert_allclose(n1, n2, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(float(l1), float(l2), rtol=3e-5)
    for nm, a, b in zip(model.param_names(), g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=3e-5, err_msg=nm)


def test_nxbp_oracle_agrees():
    """The batch-1 naive step, looped + clipped in Python exactly like
    the Rust coordinator does, matches ReweightGP."""
    model = models.MLP(784, hidden=[32])
    params = model.init_params(0)
    x, y = data_for(model)
    c = 0.5
    g_rw, _, norms_rw = clipping.reweight_step(model, params, x, y, c)
    acc = [np.zeros(p.shape, np.float32) for p in params]
    norms = []
    for i in range(TAU):
        grads, _loss, norm = baselines.naive1_step(
            model, params, x[i:i + 1], y[i:i + 1]
        )
        nu = min(1.0, c / float(norm))
        for a, g in zip(acc, grads):
            a += nu * np.asarray(g)
        norms.append(float(norm))
    np.testing.assert_allclose(norms, norms_rw, rtol=1e-4, atol=1e-5)
    for a, b in zip(acc, g_rw):
        np.testing.assert_allclose(a / TAU, b, rtol=1e-4, atol=1e-5)


def test_no_clipping_equals_nonprivate():
    """With c -> infinity, the clipped average IS the plain gradient."""
    model = models.MLP(784, hidden=[16])
    params = model.init_params(3)
    x, y = data_for(model, 3)
    g_rw, _, _ = clipping.reweight_step(model, params, x, y, 1e9)
    g_np, _ = baselines.nonprivate_step(model, params, x, y)
    for a, b in zip(g_rw, g_np):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_norms_match_true_per_example_gradients():
    """per_example_sq_norms vs explicitly materialized per-example
    gradient norms (the Sec 5 derivations are exact, not bounds)."""
    model = models.CNN()
    params = model.init_params(1)
    x, y = data_for(model, 1)
    sq = clipping.per_example_sq_norms(model, params, x, y)

    def loss_one(p, xi, yi):
        return model.loss_per_example(p, xi[None], jnp.atleast_1d(yi))[0]

    for i in range(TAU):
        g = jax.grad(loss_one)(params, x[i], y[i])
        want = sum(float(jnp.sum(gi * gi)) for gi in g)
        np.testing.assert_allclose(float(sq[i]), want, rtol=1e-4)


def test_clip_weights_formula():
    sq = jnp.array([4.0, 0.25, 1.0])
    nu, norms = clipping.clip_weights(sq, 1.0)
    np.testing.assert_allclose(norms, [2.0, 0.5, 1.0], rtol=1e-6)
    np.testing.assert_allclose(nu, [0.5, 1.0, 1.0], rtol=1e-6)


def test_reweight_gradients_are_finite_at_zero_loss():
    """Degenerate case: perfectly confident model -> tiny gradients;
    the 1/norm must not produce NaN (guarded by the 1e-24 floor)."""
    model = models.MLP(4, hidden=[4], n_classes=2)
    params = [jnp.zeros_like(p) for p in model.init_params(0)]
    x = jnp.zeros((TAU, 4))
    y = jnp.zeros((TAU,), jnp.int32)
    g, loss, norms = clipping.reweight_step(model, params, x, y, 1.0)
    assert all(bool(jnp.all(jnp.isfinite(gi))) for gi in g)
    assert bool(jnp.all(jnp.isfinite(norms)))
