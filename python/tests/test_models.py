"""Model-level sanity: shapes, parameter counts, tape behaviour, and
short-horizon trainability of each architecture."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import baselines, models
from compile.layers import Tape

jax.config.update("jax_platform_name", "cpu")


def test_mlp_matches_paper_architecture():
    """Sec 6.1.1: two hidden layers of 128 and 256 units."""
    m = models.MLP(784)
    shapes = {s.name: s.shape for s in m.param_specs()}
    assert shapes["fc0.w"] == (784, 128)
    assert shapes["fc1.w"] == (128, 256)
    assert shapes["fc2.w"] == (256, 10)


def test_cnn_matches_paper_architecture():
    """Sec 6.1.1: 20 kernels 5x5, then 50 kernels 5x5, fc 128."""
    m = models.CNN()
    shapes = {s.name: s.shape for s in m.param_specs()}
    assert shapes["conv1.w"] == (20, 1, 5, 5)
    assert shapes["conv2.w"] == (50, 20, 5, 5)
    assert shapes["fc1.w"] == (800, 128)  # 50 * 4 * 4 after two pools


def test_mlp_depth_variants():
    for depth in (2, 4, 6, 8):
        m = models.MLP(784, depth=depth)
        n_fc = sum(1 for s in m.param_specs() if s.name.endswith(".w"))
        assert n_fc == depth + 1  # hidden layers + output


@pytest.mark.parametrize(
    "build,x_shape,int_input",
    [
        (lambda: models.MLP(784), (3, 784), False),
        (lambda: models.CNN(), (3, 1, 28, 28), False),
        (lambda: models.RNNModel(), (3, 28, 28), False),
        (lambda: models.LSTMModel(), (3, 28, 28), False),
        (lambda: models.Transformer(), (3, 64), True),
        (lambda: models.ResNetMini(), (3, 3, 32, 32), False),
        (lambda: models.VGGMini(), (3, 3, 32, 32), False),
    ],
)
def test_forward_shapes_and_loss(build, x_shape, int_input):
    m = build()
    params = m.init_params(0)
    key = jax.random.PRNGKey(0)
    x = (
        jax.random.randint(key, x_shape, 0, 5000)
        if int_input
        else jax.random.normal(key, x_shape)
    )
    y = jnp.zeros((x_shape[0],), jnp.int32)
    per_ex = m.loss_per_example(params, x, y)
    assert per_ex.shape == (x_shape[0],)
    assert bool(jnp.all(jnp.isfinite(per_ex)))
    loss, correct = m.eval_metrics(params, x, y)
    assert jnp.isfinite(loss)
    assert 0 <= float(correct) <= x_shape[0]


def test_init_is_deterministic():
    a = models.CNN().init_params(7)
    b = models.CNN().init_params(7)
    c = models.CNN().init_params(8)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(not np.array_equal(x, z) for x, z in zip(a, c))


def test_tape_modes():
    m = models.MLP(16, hidden=[8])
    params = m.init_params(0)
    x = jnp.ones((2, 16))
    y = jnp.zeros((2,), jnp.int32)
    # shape mode collects one tap per linear layer
    tape = Tape(Tape.SHAPE)
    jax.eval_shape(lambda p: m.loss_sum(p, x, y, tape), params)
    assert len(tape.tap_specs) == 2  # fc0, fc1 (output layer)
    keys = [k for k, _, _ in tape.tap_specs]
    assert keys == ["fc0.z", "fc1.z"]
    # off mode records nothing
    off = Tape.off()
    m.loss_sum(params, x, y, off)
    assert off.records == [] and off.tap_specs == []
    # grad mode consumes taps and records layer inputs
    taps = {k: jnp.zeros(s, d) for k, s, d in tape.tap_specs}
    grad_tape = Tape(Tape.GRAD, taps)
    m.loss_sum(params, x, y, grad_tape)
    assert [r[0] for r in grad_tape.records] == ["linear", "linear"]


def test_duplicate_tap_key_rejected():
    tape = Tape(Tape.GRAD, {"k": jnp.zeros((1,))})
    tape.tap(jnp.zeros((1,)), "k")
    with pytest.raises(ValueError):
        tape.tap(jnp.zeros((1,)), "k")


def test_models_train_to_lower_loss():
    """A few plain-SGD steps reduce loss on a fixed batch for every
    small architecture (catches dead gradients / wiring bugs)."""
    for build, x_shape, int_input in [
        (lambda: models.MLP(64, hidden=[32]), (8, 64), False),
        (lambda: models.CNN(c_in=1, img=12), (8, 1, 12, 12), False),
        (lambda: models.RNNModel(n_in=8, n_hidden=16), (8, 6, 8), False),
    ]:
        m = build()
        params = m.init_params(0)
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, x_shape)
        y = jax.random.randint(key, (x_shape[0],), 0, 10)
        first = float(m.loss_mean(params, x, y))
        for _ in range(30):
            grads, _ = baselines.nonprivate_step(m, params, x, y)
            params = [p - 0.5 * g for p, g in zip(params, grads)]
        last = float(m.loss_mean(params, x, y))
        assert last < first - 0.05, f"{m.name}: {first} -> {last}"


def test_build_model_factory():
    assert models.build_model("mlp", in_dim=10).name == "mlp2"
    assert models.build_model("cnn").name == "cnn"
    with pytest.raises(ValueError):
        models.build_model("gpt5")


def test_transformer_embedding_frozen():
    """Embeddings carry no trainable parameters (paper: pretrained
    GloVe, frozen)."""
    m = models.Transformer()
    names = [s.name for s in m.param_specs()]
    assert not any("embed" in n for n in names)
    # but attention + layernorm + ffn + head are all trainable
    assert any("mha.wq" in n for n in names)
    assert any("ln1.gamma" in n for n in names)
    assert any("ff1.w" in n for n in names)
    assert any(n.startswith("fc.") for n in names)
