"""AOT pipeline: step functions lower to valid HLO text with the arg/
output counts the Rust runtime expects, and the manifest is coherent
with the config registry."""

import json
import os

import jax
import pytest

from compile import aot
from compile.configs import DATASETS, REGISTRY, naive_config_name

jax.config.update("jax_platform_name", "cpu")


def test_registry_is_coherent():
    for name, cfg in REGISTRY.items():
        assert cfg.name == name
        assert cfg.dataset in DATASETS
        assert cfg.batch >= 1
        assert len(set(cfg.methods)) == len(cfg.methods)
        if "naive1" in cfg.methods:
            assert cfg.batch == 1, f"{name}: naive1 must be batch-1"


def test_every_batched_config_has_a_naive_sibling():
    for name, cfg in REGISTRY.items():
        if cfg.batch > 1 and cfg.methods:
            sibling = naive_config_name(name)
            assert sibling in REGISTRY, f"{name} -> {sibling} missing"
            assert REGISTRY[sibling].model == cfg.model
            assert REGISTRY[sibling].model_kw == cfg.model_kw


def test_experiment_tags_cover_all_figures():
    tags = set()
    for cfg in REGISTRY.values():
        tags.update(cfg.tags)
    for fig in ("fig5", "fig6", "fig7", "fig8", "fig9"):
        assert fig in tags, f"no configs tagged {fig}"


@pytest.mark.parametrize("method", ["fwd", "nonprivate", "reweight", "multiloss"])
def test_lowering_small_config(tmp_path, method):
    """Lower the smallest config end-to-end and check the HLO text
    parses structurally (ENTRY, parameters, a tuple root)."""
    cfg = REGISTRY["mlp2_mnist_b16"]
    step, extra, outputs = aot.make_step_fn(cfg, method)
    specs = aot.arg_specs(cfg, method, extra)
    n_model_params = len(cfg.build_model().param_specs())
    assert len(specs) == n_model_params + 2 + len(extra)
    lowered = jax.jit(step).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "parameter(0)" in text
    # every model param + X + y (+ clip) appears as a parameter
    assert f"parameter({len(specs) - 1})" in text


def test_naive1_signature():
    cfg = REGISTRY["mlp2_mnist_b1"]
    step, extra, outputs = aot.make_step_fn(cfg, "naive1")
    assert extra == []
    assert outputs == ["grads", "loss", "norm"]
    assert cfg.input_shape[0] == 1


def test_unknown_method_rejected():
    cfg = REGISTRY["mlp2_mnist_b16"]
    with pytest.raises(ValueError):
        aot.make_step_fn(cfg, "magic")


def test_activation_elems_positive():
    for name in ("mlp2_mnist_b32", "cnn_mnist_b32", "transformer_imdb_b32"):
        cfg = REGISTRY[name]
        a = aot.activation_elems_per_example(cfg)
        assert a > 0, name
    # CNN activations dominated by first conv feature map (20x24x24)
    assert aot.activation_elems_per_example(REGISTRY["cnn_mnist_b32"]) > 10_000


MANIFEST = os.path.join(
    os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json"
)


@pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built"
)
def test_built_manifest_matches_registry():
    with open(MANIFEST) as f:
        manifest = json.load(f)
    cfgs = manifest["configs"]
    assert set(cfgs) == set(REGISTRY)
    for name, entry in cfgs.items():
        reg = REGISTRY[name]
        assert entry["batch"] == reg.batch
        assert set(entry["artifacts"]) == set(reg.methods), name
        for art in entry["artifacts"].values():
            path = os.path.join(os.path.dirname(MANIFEST), art["file"])
            assert os.path.exists(path), art["file"]
        # param shapes match a freshly built model
        model = reg.build_model()
        want = [(s.name, list(s.shape)) for s in model.param_specs()]
        got = [(p["name"], p["shape"]) for p in entry["params"]]
        assert got == want, f"{name} param mismatch"
