//! `sensitivity-consistency`: the clip bound used to calibrate noise
//! must be *the* policy sensitivity, never a recomputed expression.
//!
//! The mechanism's privacy proof is about sigma·C where C =
//! `ClipPolicy::sensitivity(...)` (or the legacy whole-model
//! `opts.clip`). If a call site hands `noise_stddev_for_mean` a clip
//! argument it derived itself (`opts.clip * 1.5`, `norms.max()`, a
//! literal), the accountant and the noise silently disagree and every
//! epsilon reported afterwards is wrong.
//!
//! The check is syntactic tracing within the defining file: the clip
//! argument must be a plain identifier path that is (or a `let`
//! binding whose right-hand side is) `ClipPolicy::sensitivity(…)` or
//! the `opts.clip` field, with no arithmetic applied. The sigma
//! handed to `add_noise_parallel` must likewise trace to a
//! `noise_stddev_for_mean(…)` result. Conservative by design:
//! an exotic-but-correct derivation needs a reasoned
//! `// lint: allow(sensitivity-consistency)`.

use super::TreeRule;
use crate::callgraph::Tree;
use crate::source::SourceFile;
use crate::tokens::{matching_delim, split_args, Tok, TokKind};
use crate::Finding;

pub struct SensitivityConsistency;

pub const ID: &str = "sensitivity-consistency";

impl TreeRule for SensitivityConsistency {
    fn id(&self) -> &'static str {
        ID
    }

    fn describe(&self) -> &'static str {
        "the clip argument of noise calibration must trace to ClipPolicy::sensitivity or opts.clip, never a recomputed expression; add_noise_parallel's sigma must trace to noise_stddev_for_mean"
    }

    fn scope(&self) -> &'static str {
        "noise_stddev_for_mean / add_noise_parallel call sites, tree-wide"
    }

    fn check(&self, tree: &Tree<'_>, out: &mut Vec<Finding>) {
        for (fi, f) in tree.files.iter().enumerate() {
            let toks = &tree.items[fi].toks;
            for (k, t) in toks.iter().enumerate() {
                if t.kind != TokKind::Ident {
                    continue;
                }
                let name = t.text(&f.code);
                let (arg_idx, validate): (usize, fn(&SourceFile, &[Tok], &str) -> Result<(), String>) =
                    match name {
                        "noise_stddev_for_mean" => (1, validate_clip_arg),
                        "add_noise_parallel" => (1, validate_sigma_arg),
                        _ => continue,
                    };
                if !toks.get(k + 1).is_some_and(|n| n.is_punct(b'(')) {
                    continue;
                }
                if k >= 1 && toks[k - 1].is_ident(&f.code, "fn") {
                    continue; // the definition
                }
                let line = f.line_of(t.start);
                if f.in_test(line) {
                    continue;
                }
                let Some(close) = matching_delim(toks, k + 1) else { continue };
                let args = split_args(&f.code, toks, k + 1, close);
                let Some(&(a_lo, a_hi)) = args.get(arg_idx) else { continue };
                let arg_text = &f.code[a_lo..a_hi];
                if let Err(why) = validate(f, toks, arg_text) {
                    out.push(Finding {
                        path: f.path.clone(),
                        line,
                        rule: ID,
                        message: format!("`{name}` argument `{}`: {why}", arg_text.trim()),
                    });
                }
            }
        }
    }
}

/// The clip bound: `.sensitivity(…)`, `…clip` (legacy), or an ident
/// that `let`-binds to one of those — nothing recomputed.
fn validate_clip_arg(f: &SourceFile, toks: &[Tok], arg: &str) -> Result<(), String> {
    let arg = arg.trim().trim_end_matches("as f64").trim();
    if arg.contains(".sensitivity(") {
        return if has_arithmetic(arg) {
            Err("arithmetic around ClipPolicy::sensitivity — pass the sensitivity itself".into())
        } else {
            Ok(())
        };
    }
    if let Some(last) = ident_path_last(arg) {
        if last == "clip" {
            return Ok(()); // legacy opts.clip path
        }
        let Some(rhs) = binding_rhs(f, toks, last) else {
            return Err(format!(
                "cannot trace `{last}` to ClipPolicy::sensitivity or opts.clip in this file"
            ));
        };
        if has_arithmetic(&rhs) {
            return Err(format!(
                "`{last}` binds to a computed expression — the clip bound must be \
                 ClipPolicy::sensitivity(…) or opts.clip verbatim"
            ));
        }
        if rhs.contains(".sensitivity(") || rhs.contains("clip") {
            return Ok(());
        }
        return Err(format!(
            "`{last}` does not derive from ClipPolicy::sensitivity or opts.clip"
        ));
    }
    Err("the clip bound must be ClipPolicy::sensitivity(…) or opts.clip, not an expression".into())
}

/// The noise stddev handed to the sampler must come from
/// `noise_stddev_for_mean` (which folds sensitivity and tau in).
fn validate_sigma_arg(f: &SourceFile, toks: &[Tok], arg: &str) -> Result<(), String> {
    let arg = arg.trim();
    if arg.contains("noise_stddev_for_mean") {
        return Ok(());
    }
    if let Some(last) = ident_path_last(arg) {
        if let Some(rhs) = binding_rhs(f, toks, last) {
            if rhs.contains("noise_stddev_for_mean") {
                return Ok(());
            }
            return Err(format!(
                "`{last}` binds to something other than noise_stddev_for_mean(…)"
            ));
        }
        // no binding in this file: accept conventionally-named
        // carriers (fields set from a traced binding elsewhere)
        if last.contains("noise_std") {
            return Ok(());
        }
        return Err(format!("cannot trace `{last}` to noise_stddev_for_mean in this file"));
    }
    Err("the noise stddev must trace to noise_stddev_for_mean(…), not an inline expression".into())
}

/// If `text` is a pure identifier path (`a.b.c`, `self.x`, `A::b`),
/// return the last segment.
fn ident_path_last(text: &str) -> Option<&str> {
    let ok = text
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == ':' || c == ' ');
    if !ok || text.is_empty() {
        return None;
    }
    text.rsplit(|c| c == '.' || c == ':')
        .next()
        .map(str::trim)
        .filter(|s| !s.is_empty() && s.chars().next().is_some_and(|c| c.is_alphabetic() || c == '_'))
}

/// Find `let [mut] name = …;` in the file (non-test) and return the
/// right-hand side's code-view text.
fn binding_rhs(f: &SourceFile, toks: &[Tok], name: &str) -> Option<String> {
    let code = &f.code;
    for (k, t) in toks.iter().enumerate() {
        if !t.is_ident(code, "let") {
            continue;
        }
        let mut j = k + 1;
        if toks.get(j).is_some_and(|t| t.is_ident(code, "mut")) {
            j += 1;
        }
        if !toks.get(j).is_some_and(|t| t.is_ident(code, name)) {
            continue;
        }
        if f.in_test(f.line_of(t.start)) {
            continue;
        }
        // optional type ascription, then `=` (not `==`)
        let mut e = j + 1;
        let mut angle = 0isize;
        while e < toks.len() {
            match toks[e].kind {
                TokKind::Punct(b'<') => angle += 1,
                TokKind::Punct(b'>') => angle -= 1,
                TokKind::Punct(b'=') if angle <= 0 => break,
                TokKind::Punct(b';') => break,
                _ => {}
            }
            e += 1;
        }
        if !toks.get(e).is_some_and(|t| t.is_punct(b'='))
            || toks.get(e + 1).is_some_and(|t| t.is_punct(b'='))
        {
            continue;
        }
        // RHS runs to the `;` at delimiter depth 0
        let mut depth = 0usize;
        let mut s = e + 1;
        let rhs_start = toks.get(s)?.start;
        while s < toks.len() {
            match toks[s].kind {
                TokKind::Punct(b'(') | TokKind::Punct(b'[') | TokKind::Punct(b'{') => depth += 1,
                TokKind::Punct(b')') | TokKind::Punct(b']') | TokKind::Punct(b'}') => {
                    depth = depth.saturating_sub(1)
                }
                TokKind::Punct(b';') if depth == 0 => {
                    return Some(code[rhs_start..toks[s].start].to_string());
                }
                _ => {}
            }
            s += 1;
        }
        return None;
    }
    None
}

/// Does the expression text contain arithmetic? `->`, `=>`, `&`, and
/// generic `<`/`>` are not arithmetic; `*`, `/`, `%`, `+`, and a
/// binary `-` are.
fn has_arithmetic(text: &str) -> bool {
    let b = text.as_bytes();
    for (i, &c) in b.iter().enumerate() {
        match c {
            b'*' | b'/' | b'%' | b'+' => return true,
            b'-' if b.get(i + 1) != Some(&b'>') => return true,
            _ => {}
        }
    }
    false
}
