//! `undocumented-unsafe`: every `unsafe` token (block, fn, impl)
//! needs a contiguous `// SAFETY:` comment immediately above it (or
//! on the same line). This is the lexical twin of clippy's
//! `undocumented_unsafe_blocks`, extended to `unsafe impl` and
//! `unsafe fn`, and it runs inside `#[cfg(test)]` code too — test
//! unsafe is still unsafe.

use super::{push, Rule};
use crate::source::SourceFile;
use crate::Finding;

pub struct UndocumentedUnsafe;

pub const ID: &str = "undocumented-unsafe";

impl Rule for UndocumentedUnsafe {
    fn id(&self) -> &'static str {
        ID
    }

    fn describe(&self) -> &'static str {
        "every unsafe block/fn/impl needs a contiguous // SAFETY: comment immediately above"
    }

    fn scope(&self) -> &'static str {
        "every linted file, test code included"
    }

    fn check(&self, f: &SourceFile, out: &mut Vec<Finding>) {
        let n_lines = f.line_starts.len();
        // lines carrying a comment that contains "SAFETY:"
        let mut safety = vec![false; n_lines];
        for c in &f.comments {
            if !c.text.contains("SAFETY:") {
                continue;
            }
            let extra = c.text.matches('\n').count();
            for k in 0..=extra {
                let l = c.line - 1 + k;
                if l < n_lines {
                    safety[l] = true;
                }
            }
        }
        for off in f.find_word("unsafe") {
            let line = f.line_of(off);
            if safety[line - 1] {
                continue; // same-line (trailing) SAFETY comment
            }
            // walk the contiguous run of comment-only lines above
            let mut l = line - 1;
            let mut documented = false;
            while l >= 1 && f.comment_on_line[l - 1] && !f.code_on_line[l - 1] {
                if safety[l - 1] {
                    documented = true;
                    break;
                }
                l -= 1;
            }
            if !documented {
                push(
                    out,
                    f,
                    line,
                    ID,
                    "`unsafe` without a contiguous `// SAFETY:` comment immediately \
                     above — state the invariant that makes this sound"
                        .to_string(),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lint_source;

    #[test]
    fn flags_bare_unsafe_block() {
        let src = "fn f(p: *mut f32) {\n    unsafe { *p = 0.0; }\n}\n";
        let f = lint_source("rust/src/util/alloc.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, super::ID);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn safety_comment_above_or_trailing_passes() {
        let above = "\
fn f(p: *mut f32) {
    // SAFETY: caller guarantees p is valid and exclusive
    unsafe { *p = 0.0; }
}
";
        assert!(lint_source("rust/src/util/alloc.rs", above).is_empty());
        let multi = "\
// SAFETY: the registry is append-only, so the pointer
// outlives every reader.
unsafe impl Send for X {}
";
        assert!(lint_source("rust/src/runtime/engine.rs", multi).is_empty());
    }

    #[test]
    fn code_between_comment_and_unsafe_breaks_contiguity() {
        let src = "\
fn f(p: *mut f32) {
    // SAFETY: stale comment
    let x = 1;
    unsafe { *p = x as f32; }
}
";
        let f = lint_source("rust/src/util/alloc.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
    }

    #[test]
    fn applies_inside_test_modules_too() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(p: *mut f32) {\n        unsafe { *p = 0.0; }\n    }\n}\n";
        let f = lint_source("rust/src/util/alloc.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
    }
}
