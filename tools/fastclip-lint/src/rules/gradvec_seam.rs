//! `gradvec-seam`: the DP proof needs every per-example gradient to
//! reach the optimizer through the clip/noise pipeline. The lexical
//! enforcement: `GradVec`'s mutating entry points may only be called
//! from the approved module set (the store itself, the engine, the
//! native kernels that fill taps, and the coordinator's method/
//! trainer pipeline). A new family that calls `.flat_mut()` from
//! somewhere else is routing gradients around the `ClipPolicy` seam.
//!
//! Deliberately *not* matched: `.add(`, `.zero(`, `.scale(` — those
//! names are too generic to attribute to `GradVec` lexically; the
//! distinctive mutators below are the ones a bypass would need.

use super::{push, Rule};
use crate::source::SourceFile;
use crate::Finding;

pub struct GradVecSeam;

pub const ID: &str = "gradvec-seam";
const MUTATORS: &[&str] = &[
    "flat_mut",
    "param_mut",
    "add_scaled",
    "add_scaled_params",
    "norms_fill",
    "set_norms",
    "set_group_norms",
];

/// The approved module set. Kept in one place so DESIGN.md and the
/// finding message can cite it verbatim.
pub fn approved(f: &SourceFile) -> bool {
    if f.has_component("native") {
        return true;
    }
    let name = f.file_name();
    (f.has_component("runtime") && (name == "store.rs" || name == "engine.rs"))
        || (f.has_component("coordinator")
            && (name == "methods.rs" || name == "trainer.rs" || name == "session.rs"))
}

impl Rule for GradVecSeam {
    fn id(&self) -> &'static str {
        ID
    }

    fn describe(&self) -> &'static str {
        "GradVec mutators (flat_mut/param_mut/add_scaled*/norms_fill/set_*norms) callable only from the approved clip/noise pipeline modules"
    }

    fn scope(&self) -> &'static str {
        "every linted file outside runtime/native/, runtime/{store,engine}.rs, coordinator/{methods,trainer,session}.rs"
    }

    fn check(&self, f: &SourceFile, out: &mut Vec<Finding>) {
        if approved(f) {
            return;
        }
        let bytes = f.code.as_bytes();
        for tok in MUTATORS {
            for off in f.find_word(tok) {
                // only method-call syntax: `.tok(`
                if off == 0 || bytes[off - 1] != b'.' {
                    continue;
                }
                if !f.code[off + tok.len()..].trim_start().starts_with('(') {
                    continue;
                }
                let line = f.line_of(off);
                if f.in_test(line) {
                    continue;
                }
                push(
                    out,
                    f,
                    line,
                    ID,
                    format!(
                        "`.{tok}(…)` outside the approved GradVec pipeline modules \
                         (runtime/store.rs, runtime/engine.rs, runtime/native/*, \
                         coordinator/methods.rs, coordinator/trainer.rs, \
                         coordinator/session.rs) — gradients must flow through \
                         the ClipPolicy seam"
                    ),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lint_source;

    #[test]
    fn flags_flat_mut_outside_pipeline() {
        let src = "fn leak(g: &mut GradVec) {\n    g.flat_mut()[0] = 1.0;\n}\n";
        let f = lint_source("rust/src/optim/adam.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, super::ID);
    }

    #[test]
    fn approved_modules_and_non_method_uses_pass() {
        let src = "fn ok(g: &mut GradVec) {\n    g.add_scaled(&other, 0.5);\n}\n";
        assert!(lint_source("rust/src/coordinator/trainer.rs", src).is_empty());
        // a free fn of the same name is not a GradVec method call
        let free = "fn f() {\n    let x = param_mut(0);\n    let _ = x;\n}\n";
        assert!(lint_source("rust/src/optim/adam.rs", free).is_empty());
    }
}
