//! `session-seam`: after the session-core refactor, model parameters
//! may change only through `Optimizer::step` driven by
//! `TrainSession::step` — the one place downstream of the clip/noise
//! pipeline. The lexical enforcement: the two operations a bypass
//! would need — `.mark_dirty()` (publishing mutated params to the
//! backends) and a `&mut …params.host` borrow (the raw weight
//! buffers) — may appear only in the approved set: the store itself,
//! the session, and the optimizers (which receive the buffers *from*
//! the session).
//!
//! Lexical limits, deliberate: the `&mut` check is per-line (a borrow
//! split across lines from its `params.host` use is not matched), and
//! read-only `params.host` uses (checkpointing, backends uploading
//! weights) pass anywhere.

use super::{push, Rule};
use crate::source::SourceFile;
use crate::Finding;

pub struct SessionSeam;

pub const ID: &str = "session-seam";

/// The approved writer set. Kept in one place so DESIGN.md and the
/// finding message can cite it verbatim.
fn approved(f: &SourceFile) -> bool {
    let name = f.file_name();
    (f.has_component("runtime") && name == "store.rs")
        || (f.has_component("coordinator") && name == "session.rs")
        || f.has_component("optim")
}

impl Rule for SessionSeam {
    fn id(&self) -> &'static str {
        ID
    }

    fn describe(&self) -> &'static str {
        "parameter mutation (.mark_dirty() / &mut …params.host) confined to runtime/store.rs, coordinator/session.rs, and optim/ — updates flow through Optimizer::step after the noise pipeline"
    }

    fn scope(&self) -> &'static str {
        "every linted file outside runtime/store.rs, coordinator/session.rs, optim/"
    }

    fn check(&self, f: &SourceFile, out: &mut Vec<Finding>) {
        if approved(f) {
            return;
        }
        let bytes = f.code.as_bytes();
        // 1. `.mark_dirty(…)` — method-call syntax only (a free fn of
        // the same name is not the ParamStore publication point)
        for off in f.find_word("mark_dirty") {
            if off == 0 || bytes[off - 1] != b'.' {
                continue;
            }
            if !f.code[off + "mark_dirty".len()..]
                .trim_start()
                .starts_with('(')
            {
                continue;
            }
            let line = f.line_of(off);
            if f.in_test(line) {
                continue;
            }
            push(
                out,
                f,
                line,
                ID,
                "`.mark_dirty(…)` outside the approved parameter-update \
                 modules (runtime/store.rs, coordinator/session.rs, optim/) \
                 — params may only change through Optimizer::step inside \
                 TrainSession::step"
                    .to_string(),
            );
        }
        // 2. `&mut …params.host` on one line — a mutable borrow of the
        // raw weight buffers outside the seam
        for off in f.find_word("params.host") {
            let line = f.line_of(off);
            if f.in_test(line) {
                continue;
            }
            let start = f.code[..off].rfind('\n').map(|p| p + 1).unwrap_or(0);
            if !f.code[start..off].contains("&mut") {
                continue;
            }
            push(
                out,
                f,
                line,
                ID,
                "`&mut …params.host` outside the approved parameter-update \
                 modules (runtime/store.rs, coordinator/session.rs, optim/) \
                 — mutable weight access bypasses the clip/noise pipeline"
                    .to_string(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lint_source;

    #[test]
    fn flags_mutation_outside_the_seam() {
        let src = "fn tweak(params: &mut ParamStore) {\n    \
                   scale(&mut params.host[0]);\n    \
                   params.mark_dirty();\n}\n";
        let f = lint_source("rust/src/coordinator/serve.rs", src);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == super::ID));
    }

    #[test]
    fn approved_modules_and_reads_pass() {
        let mutating = "fn upd(params: &mut ParamStore) {\n    \
                        opt.step(&mut params.host, &grads);\n    \
                        params.mark_dirty();\n}\n";
        assert!(lint_source("rust/src/coordinator/session.rs", mutating).is_empty());
        assert!(lint_source("rust/src/runtime/store.rs", mutating).is_empty());
        assert!(lint_source("rust/src/optim/adam.rs", mutating).is_empty());
        // read-only access is fine anywhere
        let reading = "fn count(params: &ParamStore) -> usize {\n    \
                       params.host.iter().map(|t| t.len()).sum()\n}\n";
        assert!(lint_source("rust/src/coordinator/serve.rs", reading).is_empty());
    }
}
