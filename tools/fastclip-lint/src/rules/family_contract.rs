//! `family-contract`: a model family registered with the runtime must
//! be fully wired, so a fourth family (the ROADMAP's RNN) cannot land
//! half-done and silently skip the cross-method guarantees.
//!
//! For every non-test `register("name", …)` / `register_family("name",
//! …)` call site in `runtime/` whose first argument is a string
//! literal, the rule demands:
//!
//! 1. the registering closure constructs a type with a *complete*
//!    `impl ModelFamily` — every method the trait declares without a
//!    default body is present in the impl;
//! 2. if the linted tree carries an agreement-matrix test (a fn whose
//!    name contains `agree`), some such fn mentions the family;
//! 3. if the linted tree carries `no_alloc.rs`, it names a config of
//!    the family (the steady-state allocation-free guarantee);
//! 4. if the linted tree carries a policy-oracle test (a fn whose
//!    name contains `oracle`), some such fn mentions the family.
//!
//! Witnesses 2–4 are conditional on the witness file/fn being in the
//! linted tree, so linting `rust/src` alone stays clean while the CI
//! invocation over `rust/src rust/tests` enforces the full contract.
//! A family is "mentioned" when an identifier or string literal
//! starts with its name followed by a digit, `_`, `(`, or the end of
//! the literal — matching config keys like `cnn2_mnist_b16` and spec
//! strings like `mlp(depth=3,…)`.

use super::TreeRule;
use crate::callgraph::Tree;
use crate::source::SourceFile;
use crate::tokens::{matching_delim, TokKind};
use crate::Finding;

pub struct FamilyContract;

pub const ID: &str = "family-contract";

impl TreeRule for FamilyContract {
    fn id(&self) -> &'static str {
        ID
    }

    fn describe(&self) -> &'static str {
        "every registered model family implements the full ModelFamily norm-route surface and appears in the agreement matrix, no_alloc.rs, and the policy-oracle test"
    }

    fn scope(&self) -> &'static str {
        "register sites under runtime/; witnesses anywhere in the linted tree (conditional on presence)"
    }

    fn check(&self, tree: &Tree<'_>, out: &mut Vec<Finding>) {
        // the trait's required surface (first ModelFamily decl wins)
        let required: Option<&Vec<String>> = tree
            .items
            .iter()
            .flat_map(|idx| idx.traits.iter())
            .find(|t| t.name == "ModelFamily")
            .map(|t| &t.required_fns);

        // every complete-enough impl target type in the tree
        let impl_types: Vec<(usize, &str, (usize, usize))> = tree
            .items
            .iter()
            .enumerate()
            .flat_map(|(fi, idx)| {
                idx.impls
                    .iter()
                    .filter(|im| im.trait_name.as_deref() == Some("ModelFamily"))
                    .map(move |im| (fi, im.type_name.as_str(), im.body))
            })
            .collect();

        // witness inventory
        let no_alloc_file: Option<usize> =
            tree.files.iter().position(|f| f.file_name() == "no_alloc.rs");
        let agree_fns = fns_named_like(tree, "agree");
        let oracle_fns = fns_named_like(tree, "oracle");

        for (fi, f) in tree.files.iter().enumerate() {
            if !f.has_component("runtime") {
                continue;
            }
            for (line, call_span, family) in register_sites(tree, fi, f) {
                let mut missing: Vec<String> = Vec::new();

                // 1. a complete ModelFamily impl constructed here
                let site_idents: Vec<&str> = tree.items[fi]
                    .toks
                    .iter()
                    .filter(|t| {
                        t.kind == TokKind::Ident
                            && t.start >= call_span.0
                            && t.end <= call_span.1
                    })
                    .map(|t| t.text(&f.code))
                    .collect();
                let linked = impl_types.iter().find(|(_, ty, _)| site_idents.contains(ty));
                match linked {
                    None => missing.push(
                        "a type implementing ModelFamily constructed at the register site"
                            .to_string(),
                    ),
                    Some((ifi, ty, body)) => {
                        if let Some(req) = required {
                            let have: Vec<&str> = tree.items[*ifi]
                                .fns_in(*body)
                                .map(|fun| fun.name.as_str())
                                .collect();
                            let absent: Vec<&str> = req
                                .iter()
                                .map(|r| r.as_str())
                                .filter(|r| !have.contains(r))
                                .collect();
                            if !absent.is_empty() {
                                missing.push(format!(
                                    "ModelFamily methods on `{ty}`: {}",
                                    absent.join(", ")
                                ));
                            }
                        }
                    }
                }

                // 2. agreement matrix coverage
                if !agree_fns.is_empty()
                    && !agree_fns
                        .iter()
                        .any(|&(wfi, span)| mentions_family(&tree.files[wfi], span, &family))
                {
                    missing.push("a row in the method-agreement matrix tests".to_string());
                }

                // 3. no_alloc.rs coverage
                if let Some(na) = no_alloc_file {
                    let naf = &tree.files[na];
                    if !mentions_family(naf, (0, naf.raw.len()), &family) {
                        missing.push("a config row in no_alloc.rs".to_string());
                    }
                }

                // 4. policy-oracle coverage
                if !oracle_fns.is_empty()
                    && !oracle_fns
                        .iter()
                        .any(|&(wfi, span)| mentions_family(&tree.files[wfi], span, &family))
                {
                    missing.push("the nxBP policy-oracle test".to_string());
                }

                if !missing.is_empty() {
                    out.push(Finding {
                        path: f.path.clone(),
                        line,
                        rule: ID,
                        message: format!(
                            "family {family:?} is registered but not fully wired — missing: {}",
                            missing.join("; ")
                        ),
                    });
                }
            }
        }
    }
}

/// Non-test `register`/`register_family` call sites in file `fi` with
/// a leading string-literal argument: (line, full call span, family).
fn register_sites(tree: &Tree<'_>, fi: usize, f: &SourceFile) -> Vec<(usize, (usize, usize), String)> {
    let toks = &tree.items[fi].toks;
    let mut out = Vec::new();
    for (k, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident {
            continue;
        }
        let name = t.text(&f.code);
        if name != "register" && name != "register_family" {
            continue;
        }
        if !toks.get(k + 1).is_some_and(|n| n.is_punct(b'(')) {
            continue;
        }
        if k >= 1 && toks[k - 1].is_ident(&f.code, "fn") {
            continue; // the definition
        }
        let line = f.line_of(t.start);
        if f.in_test(line) {
            continue;
        }
        let Some(close) = matching_delim(toks, k + 1) else { continue };
        // first-argument span by token offsets: from after `(` to the
        // first top-level comma (or the `)`). The literal's bytes are
        // blanked in the code view, so a text-trimmed span would
        // collapse to nothing — offsets still bracket the literal.
        let a_lo = toks[k + 1].end;
        let mut a_hi = toks[close].start;
        let mut depth = 0usize;
        for t in &toks[k + 2..close] {
            match t.kind {
                TokKind::Punct(b'(') | TokKind::Punct(b'[') | TokKind::Punct(b'{') => depth += 1,
                TokKind::Punct(b')') | TokKind::Punct(b']') | TokKind::Punct(b'}') => {
                    depth = depth.saturating_sub(1)
                }
                TokKind::Punct(b',') if depth == 0 => {
                    a_hi = t.start;
                    break;
                }
                _ => {}
            }
        }
        let Some(lit) = f.strings.iter().find(|s| s.off >= a_lo && s.off < a_hi) else {
            continue; // family name not a literal: out of this rule's reach
        };
        out.push((line, (t.start, toks[close].end), lit.text.clone()));
    }
    out
}

/// Witness fns: fns in `tests/`-directory files whose name contains
/// `frag`, as (file index, body span). Restricted to the integration
/// test tree on purpose — unit-test helpers inside `src` with
/// agree/oracle-ish names are not the cross-family matrix.
fn fns_named_like(tree: &Tree<'_>, frag: &str) -> Vec<(usize, (usize, usize))> {
    let mut out = Vec::new();
    for (fi, idx) in tree.items.iter().enumerate() {
        if !tree.files[fi].has_component("tests") {
            continue;
        }
        for fun in &idx.fns {
            if let Some(body) = fun.body {
                if fun.name.contains(frag) {
                    out.push((fi, body));
                }
            }
        }
    }
    out
}

/// Does `f` mention family `name` inside `span` — as a code
/// identifier or a string literal starting with the name followed by
/// a digit, `_`, `(`, or the end?
fn mentions_family(f: &SourceFile, span: (usize, usize), name: &str) -> bool {
    let follows_ok = |rest: &str| {
        rest.is_empty()
            || rest.starts_with(|c: char| c.is_ascii_digit() || c == '_' || c == '(')
    };
    for s in &f.strings {
        if s.off >= span.0 && s.off < span.1 {
            if let Some(rest) = s.text.strip_prefix(name) {
                if follows_ok(rest) {
                    return true;
                }
            }
        }
    }
    // code identifiers starting with the family name
    let lo = span.0.min(f.code.len());
    let hi = span.1.min(f.code.len());
    let hay = &f.code[lo..hi];
    let bytes = hay.as_bytes();
    let mut from = 0;
    while let Some(p) = hay[from..].find(name) {
        let at = from + p;
        let before_ok = at == 0
            || !(bytes[at - 1] == b'_' || bytes[at - 1].is_ascii_alphanumeric());
        let rest = &hay[at + name.len()..];
        if before_ok && follows_ok(rest) {
            return true;
        }
        from = at + name.len();
    }
    false
}
