//! `rayon-disjoint-mut`: the determinism contract allows parallel
//! mutation only through *disjoint views* — `par_chunks_mut`,
//! `par_iter_mut`, or the gemm/conv helpers that split output rows
//! into non-overlapping blocks. A `for_each` driven by
//! `into_par_iter`/`par_bridge` over indices invites shared-`&mut`
//! index arithmetic where writes can overlap (UB) or race on order.

use super::{push, Rule};
use crate::source::SourceFile;
use crate::Finding;

pub struct RayonDisjointMut;

pub const ID: &str = "rayon-disjoint-mut";
const SCOPES: &[&str] = &["runtime", "rng", "coordinator", "privacy"];
/// Modules implementing the blocked-disjoint pattern itself; their
/// index arithmetic is the approved primitive others must call.
const APPROVED_FILES: &[&str] = &["gemm.rs", "conv.rs"];
const BAD_SOURCES: &[&str] = &["into_par_iter", "par_bridge"];

impl Rule for RayonDisjointMut {
    fn id(&self) -> &'static str {
        ID
    }

    fn describe(&self) -> &'static str {
        "rayon mutation only via disjoint views (par_chunks_mut/par_iter_mut) outside the approved gemm/conv helpers"
    }

    fn scope(&self) -> &'static str {
        "runtime/, rng/, coordinator/, privacy/ (gemm.rs and conv.rs approved)"
    }

    fn check(&self, f: &SourceFile, out: &mut Vec<Finding>) {
        if !SCOPES.iter().any(|d| f.has_component(d)) {
            return;
        }
        if f.has_component("native") && APPROVED_FILES.contains(&f.file_name()) {
            return;
        }
        for off in f.find_word("for_each") {
            let line = f.line_of(off);
            if f.in_test(line) {
                continue;
            }
            // the iterator chain feeding this for_each: scan back to
            // the start of the statement in the code view
            let stmt_start = f.code[..off].rfind(';').map(|p| p + 1).unwrap_or(0);
            let chain = &f.code[stmt_start..off];
            for src in BAD_SOURCES {
                if chain.contains(src) {
                    push(
                        out,
                        f,
                        line,
                        ID,
                        format!(
                            "`{src}` feeding a `for_each` — parallel mutation must \
                             go through disjoint views (`par_chunks_mut`, \
                             `par_iter_mut`) or the approved gemm/conv row-block \
                             helpers, so writes cannot overlap"
                        ),
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lint_source;

    #[test]
    fn flags_into_par_iter_for_each() {
        let src = "fn f(out: &mut [f32]) {\n    (0..4).into_par_iter().for_each(|i| {\n        let p = out.as_mut_ptr();\n    });\n}\n";
        let f = lint_source("rust/src/runtime/native/relu.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, super::ID);
    }

    #[test]
    fn par_chunks_mut_passes_and_gemm_is_approved() {
        let good = "fn f(out: &mut [f32]) {\n    out.par_chunks_mut(16).for_each(|c| c.fill(0.0));\n}\n";
        assert!(lint_source("rust/src/rng/gaussian.rs", good).is_empty());
        let bad_but_approved =
            "fn f() {\n    (0..4).into_par_iter().for_each(|_i| {});\n}\n";
        assert!(lint_source("rust/src/runtime/native/gemm.rs", bad_but_approved).is_empty());
    }
}
