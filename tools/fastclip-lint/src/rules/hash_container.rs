//! `no-hash-container`: determinism-scoped modules must not use
//! `HashMap`/`HashSet`. Their iteration order is randomized per
//! process (SipHash keys), so any artifact, manifest, or dispatch
//! order derived from one silently varies across runs — exactly the
//! class of drift the bitwise-determinism contract forbids.

use super::{push, Rule};
use crate::source::SourceFile;
use crate::Finding;

pub struct HashContainer;

pub const ID: &str = "no-hash-container";
const SCOPES: &[&str] = &["runtime", "coordinator", "privacy"];
const TOKENS: &[&str] = &["HashMap", "HashSet"];

impl Rule for HashContainer {
    fn id(&self) -> &'static str {
        ID
    }

    fn describe(&self) -> &'static str {
        "no HashMap/HashSet in runtime/, coordinator/, privacy/ (nondeterministic iteration order) — use BTreeMap/BTreeSet"
    }

    fn scope(&self) -> &'static str {
        "runtime/, coordinator/, privacy/, data/stream.rs, data/source.rs"
    }

    fn check(&self, f: &SourceFile, out: &mut Vec<Finding>) {
        // the streaming data path (PR 8) feeds the deterministic
        // runtime and is held to the same bar as the pinned dirs
        let data_stream = f.has_component("data")
            && matches!(f.file_name(), "stream.rs" | "source.rs");
        let scope = match SCOPES.iter().find(|d| f.has_component(d)) {
            Some(s) => *s,
            None if data_stream => "data",
            None => return,
        };
        for tok in TOKENS {
            for off in f.find_word(tok) {
                let line = f.line_of(off);
                if f.in_test(line) {
                    continue;
                }
                push(
                    out,
                    f,
                    line,
                    ID,
                    format!(
                        "`{tok}` in a determinism-scoped module ({scope}/): iteration \
                         order is randomized per process — use BTreeMap/BTreeSet or \
                         pin the order explicitly"
                    ),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lint_source;

    #[test]
    fn flags_hashmap_in_runtime() {
        let f = lint_source(
            "rust/src/runtime/engine.rs",
            "use std::collections::HashMap;\n",
        );
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, super::ID);
    }

    #[test]
    fn flags_hashmap_in_the_streaming_data_path() {
        for file in ["stream.rs", "source.rs"] {
            let f = lint_source(
                &format!("rust/src/data/{file}"),
                "use std::collections::HashMap;\n",
            );
            assert_eq!(f.len(), 1, "{file}");
            assert_eq!(f[0].rule, super::ID);
        }
    }

    #[test]
    fn ignores_out_of_scope_and_test_code() {
        let outside = lint_source("rust/src/data/batcher.rs", "use std::collections::HashMap;\n");
        assert!(outside.is_empty());
        let in_test = lint_source(
            "rust/src/runtime/engine.rs",
            "#[cfg(test)]\nmod tests {\n    use std::collections::HashSet;\n}\n",
        );
        assert!(in_test.is_empty(), "{in_test:?}");
    }
}
