//! `dp-flow`: the paper's flow discipline, checked interprocedurally.
//!
//! Three obligations over the call-graph effect summaries
//! (`callgraph.rs`):
//!
//! (a) a function that *directly* steps the optimizer and reaches
//!     gradient production must also reach a nu-application and a
//!     noise-addition — no path from per-example gradients to
//!     `Optimizer::step` may skip the clip/noise pipeline;
//! (b) a function that directly adds noise must reach an accountant
//!     charge (the serve scheduler's one-step-ahead ledger probe
//!     counts — its `probe.step(…)` is an accountant charge);
//! (c) in `runtime/native/`, every *private* leaf dispatch arm
//!     (`Kind::Reweight*`, `Kind::MultiLoss`) whose path writes
//!     gradients must have a nu-application on that same path — so
//!     one batched method cannot silently drop clipping while its
//!     siblings keep the agreement tests green.
//!
//! Soundness direction: effects are over-approximated (name-based
//! resolution unions every same-named callee), so (a)–(c) can miss a
//! violation only if an *unrelated* same-named function provides the
//! missing edge; they cannot fire spuriously on code that really
//! performs the edge. The nu/noise/charge seeds are deliberately
//! narrow (see `callgraph.rs`) so deleting the real call is detected.

use super::TreeRule;
use crate::callgraph::{Tree, ADDS_NOISE, APPLIES_NU, CHARGES_ACCT, WRITES_GRAD};
use crate::items::EXEMPT_KINDS;
use crate::Finding;

pub struct DpFlow;

pub const ID: &str = "dp-flow";

impl TreeRule for DpFlow {
    fn id(&self) -> &'static str {
        ID
    }

    fn describe(&self) -> &'static str {
        "no path from gradient production to Optimizer::step without nu-application and noise-addition; no noise without an accountant charge; every private batched-method arm applies nu"
    }

    fn scope(&self) -> &'static str {
        "call graph over the whole linted tree (optimizer steps, noise sites, runtime/native dispatch arms)"
    }

    fn check(&self, tree: &Tree<'_>, out: &mut Vec<Finding>) {
        for (idx, node) in tree.nodes.iter().enumerate() {
            let f = tree.file_of(node);

            // (a) optimizer step fed by gradients needs nu + noise
            if let Some(&line) = node.opt_step_lines.first() {
                if node.reach & WRITES_GRAD != 0 {
                    let mut missing = Vec::new();
                    if node.reach & APPLIES_NU == 0 {
                        missing.push("a nu-application (clip)");
                    }
                    if node.reach & ADDS_NOISE == 0 {
                        missing.push("a noise-addition");
                    }
                    if !missing.is_empty() {
                        out.push(Finding {
                            path: f.path.clone(),
                            line,
                            rule: ID,
                            message: format!(
                                "`{}` steps the optimizer on produced gradients without {} \
                                 edge reachable on the path — the DP-SGD pipeline is \
                                 clip → noise → account → step",
                                node.display,
                                missing.join(" or ")
                            ),
                        });
                    }
                }
            }

            // (b) noise must be accounted
            if let Some(&line) = node.noise_lines.first() {
                if node.reach & CHARGES_ACCT == 0 {
                    out.push(Finding {
                        path: f.path.clone(),
                        line,
                        rule: ID,
                        message: format!(
                            "`{}` adds noise but no accountant charge is reachable — \
                             every noised step must be charged to the RDP ledger \
                             (`accountant.step(q, sigma)` or the serve probe)",
                            node.display
                        ),
                    });
                }
            }

            // (c) private native dispatch arms apply nu themselves
            if node.is_leaf_arm
                && f.has_component("native")
                && !node.kinds.is_empty()
                && node.kinds.iter().all(|k| !EXEMPT_KINDS.contains(&k.as_str()))
            {
                let path_eff = tree.path_effects(idx);
                if path_eff & WRITES_GRAD != 0 && path_eff & APPLIES_NU == 0 {
                    out.push(Finding {
                        path: f.path.clone(),
                        line: node.line,
                        rule: ID,
                        message: format!(
                            "private batched method `{}` ({}) writes gradients with no \
                             nu-application on its dispatch path — the per-example clip \
                             factor must scale this method's gradient route",
                            node.display,
                            node.kinds.join("|")
                        ),
                    });
                }
            }
        }
    }
}
