//! `no-wallclock-entropy`: `runtime/` is the replayable core — given
//! the same inputs and seed it must produce bit-identical steps. Wall
//! clocks, ambient RNGs, and environment variables are hidden inputs
//! that break replay (and make DP accounting unauditable), so they
//! may not appear there without an explicit allow.
//!
//! `coordinator/serve.rs` is held to the same bar: the multi-job
//! scheduler promises per-job bitwise equality with solo runs, which
//! dies the moment admission or interleaving order reads a clock or
//! the environment.

use super::{push, Rule};
use crate::source::SourceFile;
use crate::Finding;

pub struct WallclockEntropy;

pub const ID: &str = "no-wallclock-entropy";
const TOKENS: &[&str] = &[
    "std::time",
    "SystemTime",
    "Instant",
    "thread_rng",
    "rand::random",
    "std::env",
    "env::var",
    "env::vars",
];

impl Rule for WallclockEntropy {
    fn id(&self) -> &'static str {
        ID
    }

    fn describe(&self) -> &'static str {
        "no std::time / thread_rng / env reads in runtime/ or coordinator/serve.rs — hidden inputs break replayable, seeded execution"
    }

    fn scope(&self) -> &'static str {
        "runtime/, coordinator/serve.rs, data/stream.rs, data/source.rs"
    }

    fn check(&self, f: &SourceFile, out: &mut Vec<Finding>) {
        let serve_scheduler =
            f.has_component("coordinator") && f.file_name() == "serve.rs";
        // the streaming data path (PR 8) must replay batches bit-for-
        // bit from (path, seed, epoch) — same hidden-input ban
        let data_stream = f.has_component("data")
            && matches!(f.file_name(), "stream.rs" | "source.rs");
        if !(f.has_component("runtime") || serve_scheduler || data_stream) {
            return;
        }
        let scope = if serve_scheduler {
            "coordinator/serve.rs"
        } else if data_stream {
            "the streaming data path"
        } else {
            "runtime/"
        };
        for tok in TOKENS {
            for off in f.find_word(tok) {
                let line = f.line_of(off);
                if f.in_test(line) {
                    continue;
                }
                push(
                    out,
                    f,
                    line,
                    ID,
                    format!(
                        "`{tok}` in {scope}: wall clocks, ambient RNGs, and env \
                         reads are hidden inputs — thread seeds/config through \
                         StepSpec instead"
                    ),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lint_source;

    #[test]
    fn flags_instant_in_runtime() {
        let f = lint_source(
            "rust/src/runtime/hot.rs",
            "use std::time::Instant;\nfn t() { let _ = Instant::now(); }\n",
        );
        assert_eq!(f.len(), 2, "{f:?}"); // one per line, deduped within a line
        assert!(f.iter().all(|x| x.rule == super::ID));
    }

    #[test]
    fn flags_instant_in_the_serve_scheduler() {
        let f = lint_source(
            "rust/src/coordinator/serve.rs",
            "use std::time::Instant;\nfn t() { let _ = Instant::now(); }\n",
        );
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == super::ID));
    }

    #[test]
    fn flags_env_reads_in_the_streaming_data_path() {
        for file in ["stream.rs", "source.rs"] {
            let f = lint_source(
                &format!("rust/src/data/{file}"),
                "fn open() { let _ = std::env::var(\"FASTCLIP_DATA_DIR\"); }\n",
            );
            assert!(!f.is_empty(), "{file}");
            assert!(f.iter().all(|x| x.rule == super::ID));
        }
    }

    #[test]
    fn coordinator_may_read_env() {
        let f = lint_source(
            "rust/src/coordinator/cli.rs",
            "fn t() -> Option<String> { std::env::var(\"X\").ok() }\n",
        );
        assert!(f.is_empty(), "{f:?}");
    }
}
