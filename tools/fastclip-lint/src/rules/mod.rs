//! The rule registry. Every rule is a lexical/structural check over a
//! [`SourceFile`](crate::source::SourceFile); path scoping (which
//! directories a rule patrols) lives inside each rule so fixtures can
//! exercise it with virtual paths.
//!
//! Adding a rule: write a unit struct implementing [`Rule`] in a new
//! submodule, register it in [`all`], and add `bad.rs` / `good.rs`
//! fixtures under `tests/fixtures/<rule-id>/`. The meta-test in
//! `tests/ui.rs` will then hold the real tree to it.

mod f32_accum;
mod gradvec_seam;
mod hash_container;
mod rayon_disjoint;
mod session_seam;
mod unsafe_comment;
mod wallclock_entropy;

use crate::source::SourceFile;
use crate::Finding;

/// A single named check.
pub trait Rule: Sync {
    /// Stable id used in findings and `lint: allow(...)` annotations.
    fn id(&self) -> &'static str;
    /// One-line description for `--list-rules` and docs.
    fn describe(&self) -> &'static str;
    /// Append findings for `f`. Suppression is the engine's job —
    /// rules report everything they see.
    fn check(&self, f: &SourceFile, out: &mut Vec<Finding>);
}

/// All registered rules, in reporting order.
pub fn all() -> &'static [&'static dyn Rule] {
    static RULES: [&'static dyn Rule; 7] = [
        &hash_container::HashContainer,
        &wallclock_entropy::WallclockEntropy,
        &rayon_disjoint::RayonDisjointMut,
        &f32_accum::F32Accum,
        &unsafe_comment::UndocumentedUnsafe,
        &gradvec_seam::GradVecSeam,
        &session_seam::SessionSeam,
    ];
    &RULES
}

/// Shared helper: record a finding at a 1-based line.
pub(crate) fn push(
    out: &mut Vec<Finding>,
    f: &SourceFile,
    line: usize,
    rule: &'static str,
    message: String,
) {
    out.push(Finding {
        path: f.path.clone(),
        line,
        rule,
        message,
    });
}
