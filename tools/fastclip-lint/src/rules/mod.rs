//! The rule registry. Rules come in two shapes:
//!
//! * [`Rule`] — a lexical/structural check over one
//!   [`SourceFile`](crate::source::SourceFile); path scoping (which
//!   directories a rule patrols) lives inside each rule so fixtures
//!   can exercise it with virtual paths.
//! * [`TreeRule`] — an interprocedural check over the call graph
//!   ([`Tree`](crate::callgraph::Tree)) built from *every* linted
//!   file at once (dp-flow, the family contract, sensitivity
//!   tracing). Tree rules see cross-file facts a per-file rule
//!   cannot.
//!
//! Adding a rule: write a unit struct implementing [`Rule`] (or
//! [`TreeRule`]) in a new submodule, register it in [`all`] (or
//! [`tree_rules`]), and add `bad.rs` / `good.rs` fixtures — or
//! `bad/` / `good/` directories of `//@ path:`-tagged files for
//! multi-file rules — under `tests/fixtures/<rule-id>/`. The
//! meta-test in `tests/ui.rs` will then hold the real tree to it.

mod dp_flow;
mod f32_accum;
mod family_contract;
mod gradvec_seam;
mod hash_container;
mod rayon_disjoint;
mod sensitivity_consistency;
mod session_seam;
mod unsafe_comment;
mod wallclock_entropy;

use crate::callgraph::Tree;
use crate::source::SourceFile;
use crate::Finding;

/// A single named per-file check.
pub trait Rule: Sync {
    /// Stable id used in findings and `lint: allow(...)` annotations.
    fn id(&self) -> &'static str;
    /// One-line description for `--list-rules` and docs.
    fn describe(&self) -> &'static str;
    /// Where the rule looks, for `--list-rules` and docs.
    fn scope(&self) -> &'static str;
    /// Append findings for `f`. Suppression is the engine's job —
    /// rules report everything they see.
    fn check(&self, f: &SourceFile, out: &mut Vec<Finding>);
}

/// A single named whole-tree check over the call graph.
pub trait TreeRule: Sync {
    fn id(&self) -> &'static str;
    fn describe(&self) -> &'static str;
    fn scope(&self) -> &'static str;
    /// Append findings for the linted tree. Suppression is still the
    /// engine's job, applied per finding against its file.
    fn check(&self, tree: &Tree<'_>, out: &mut Vec<Finding>);
}

/// All registered per-file rules, in reporting order.
pub fn all() -> &'static [&'static dyn Rule] {
    static RULES: [&'static dyn Rule; 7] = [
        &hash_container::HashContainer,
        &wallclock_entropy::WallclockEntropy,
        &rayon_disjoint::RayonDisjointMut,
        &f32_accum::F32Accum,
        &unsafe_comment::UndocumentedUnsafe,
        &gradvec_seam::GradVecSeam,
        &session_seam::SessionSeam,
    ];
    &RULES
}

/// All registered tree rules, in reporting order.
pub fn tree_rules() -> &'static [&'static dyn TreeRule] {
    static RULES: [&'static dyn TreeRule; 3] = [
        &dp_flow::DpFlow,
        &family_contract::FamilyContract,
        &sensitivity_consistency::SensitivityConsistency,
    ];
    &RULES
}

/// Shared helper: record a finding at a 1-based line.
pub(crate) fn push(
    out: &mut Vec<Finding>,
    f: &SourceFile,
    line: usize,
    rule: &'static str,
    message: String,
) {
    out.push(Finding {
        path: f.path.clone(),
        line,
        rule,
        message,
    });
}
