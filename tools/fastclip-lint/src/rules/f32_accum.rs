//! `f32-accum`: float reductions in `runtime/native/` must go through
//! the contract's helpers — fixed ascending-order loops or the
//! `sgemm_tn_f64acc` f64 accumulators in `gemm.rs`. A bare
//! `.sum::<f32>()` or an ad-hoc `let mut acc = 0.0f32; … acc += …`
//! loop re-introduces order- and width-dependent rounding, which is
//! exactly what makes per-example norms drift between code paths.

use super::{push, Rule};
use crate::source::SourceFile;
use crate::Finding;

pub struct F32Accum;

pub const ID: &str = "f32-accum";
/// The module that *implements* the approved accumulation helpers.
const APPROVED_FILE: &str = "gemm.rs";

impl Rule for F32Accum {
    fn id(&self) -> &'static str {
        ID
    }

    fn describe(&self) -> &'static str {
        "float accumulation in runtime/native/ must use the ascending-order / f64-accumulator helpers (no bare .sum::<f32>() or f32 += loops)"
    }

    fn scope(&self) -> &'static str {
        "runtime/native/ (gemm.rs approved)"
    }

    fn check(&self, f: &SourceFile, out: &mut Vec<Finding>) {
        if !f.has_component("native") || f.file_name() == APPROVED_FILE {
            return;
        }
        for off in f.find_word("sum::<f32>") {
            let line = f.line_of(off);
            if f.in_test(line) {
                continue;
            }
            push(
                out,
                f,
                line,
                ID,
                "bare `.sum::<f32>()` — reduction order/width is unspecified; use \
                 the ascending-order or f64-accumulator helpers in gemm.rs"
                    .to_string(),
            );
        }
        scan_scalar_accumulators(f, out);
    }
}

/// Flag `let mut <id> = 0.0f32`-style declarations whose `<id> += …`
/// happens in a *nested* block (a reduction loop). Same-depth `+=` is
/// fine — that's a running update, not an order-sensitive reduction.
fn scan_scalar_accumulators(f: &SourceFile, out: &mut Vec<Finding>) {
    let n_lines = f.line_starts.len();
    for l in 1..=n_lines {
        if f.in_test(l) {
            continue;
        }
        let lc = f.code_line(l);
        let ident = match f32_zero_decl(lc) {
            Some(id) => id,
            None => continue,
        };
        let decl_depth = f.depth_at_line[l - 1];
        let mut m = l + 1;
        while m <= n_lines && f.depth_at_line[m - 1] >= decl_depth {
            let mc = f.code_line(m);
            if let Some(plus_line_depth) = add_assign_depth(mc, &ident, f.depth_at_line[m - 1]) {
                if plus_line_depth > decl_depth && !f.in_test(m) {
                    push(
                        out,
                        f,
                        l,
                        ID,
                        format!(
                            "f32 accumulator `{ident}` (declared here, `+=` in a \
                             nested loop at line {m}) — accumulate in f64 or use \
                             the fixed ascending-order helpers in gemm.rs"
                        ),
                    );
                    break;
                }
            }
            m += 1;
        }
    }
}

/// If `line` declares a zero-initialized f32 (`let mut acc = 0.0f32;`
/// or `let mut acc: f32 = 0.0;`), return the identifier.
fn f32_zero_decl(line: &str) -> Option<String> {
    let at = line.find("let mut ")?;
    let rest = &line[at + "let mut ".len()..];
    let ident: String = rest
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect();
    if ident.is_empty() {
        return None;
    }
    let tail = &rest[ident.len()..];
    if tail.contains("f32") && tail.contains("= 0") {
        Some(ident)
    } else {
        None
    }
}

/// If `line` contains `<ident> += …`, return the brace depth at the
/// `+=` (line-start depth adjusted for braces earlier on the line).
fn add_assign_depth(line: &str, ident: &str, line_start_depth: usize) -> Option<usize> {
    for at in crate::source::find_word_in(line, ident) {
        let after = line[at + ident.len()..].trim_start();
        if after.starts_with("+=") {
            let mut depth = line_start_depth;
            for ch in line[..at].chars() {
                match ch {
                    '{' => depth += 1,
                    '}' => depth = depth.saturating_sub(1),
                    _ => {}
                }
            }
            return Some(depth);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use crate::lint_source;

    #[test]
    fn flags_sum_f32_and_nested_accumulator() {
        let src = "\
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let quick: f32 = a.iter().sum::<f32>();
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc + quick
}
";
        let f = lint_source("rust/src/runtime/native/mlp.rs", src);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == super::ID));
        assert_eq!(f[0].line, 2);
        assert_eq!(f[1].line, 3); // reported at the declaration
    }

    #[test]
    fn f64_accumulator_and_same_depth_update_pass() {
        let src = "\
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        acc += (a[i] * b[i]) as f64;
    }
    let mut running = 0.0f32;
    running += acc as f32;
    running
}
";
        let f = lint_source("rust/src/runtime/native/mlp.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }
}
