//! The file model every rule consumes: a comment-and-string-blanked
//! *code view* of the source (same byte length, so offsets and line
//! numbers agree with the original), the comment list, per-line brace
//! depth, and the `#[cfg(test)] mod` mask.
//!
//! The lexer understands line comments, nested block comments, string
//! / raw-string / byte-string literals, char literals, and lifetimes
//! (so `'a` does not open a char literal). It does not build an AST —
//! every rule in this tool is a lexical/structural check, which keeps
//! the tool dependency-free.

/// One comment's text, attributed to the line it starts on.
#[derive(Debug, Clone)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line: usize,
    /// Text after `//` (line) or between `/*` and `*/` (block).
    pub text: String,
}

/// One string literal's contents, attributed to where it starts. The
/// code view blanks literals, so rules that need their text (e.g. the
/// family names at `register(...)` sites) read them from here.
#[derive(Debug, Clone)]
pub struct StrLit {
    /// 1-based line the literal starts on.
    pub line: usize,
    /// Byte offset of the opening delimiter in the raw text.
    pub off: usize,
    /// Literal contents, delimiters excluded, escapes left as written.
    pub text: String,
}

/// A parsed source file plus the derived views the rules need.
#[derive(Debug)]
pub struct SourceFile {
    /// Path used for scoping decisions, normalized to `/` separators.
    pub path: String,
    /// Original text.
    pub raw: String,
    /// Same length as `raw`: comments and literal contents (including
    /// their delimiters) replaced by spaces, newlines preserved.
    pub code: String,
    /// Byte offset where each 0-based line starts.
    pub line_starts: Vec<usize>,
    /// Per 0-based line: inside a `#[cfg(test)] mod … { … }` body.
    pub test_mask: Vec<bool>,
    /// Per 0-based line: the line has at least one comment on it.
    pub comment_on_line: Vec<bool>,
    /// Per 0-based line: the line has non-whitespace *code* on it.
    pub code_on_line: Vec<bool>,
    /// Per 0-based line: brace depth at the start of the line.
    pub depth_at_line: Vec<usize>,
    /// All comments in order.
    pub comments: Vec<Comment>,
    /// All string literals in order (contents only — blanked in `code`).
    pub strings: Vec<StrLit>,
}

impl SourceFile {
    pub fn parse(path: &str, raw: &str) -> SourceFile {
        let (code, comments, strings) = blank_non_code(raw);
        let line_starts = line_starts(raw);
        let n_lines = line_starts.len();

        // mark every line a comment touches (block comments span lines)
        let mut comment_on_line = vec![false; n_lines];
        for c in &comments {
            let extra = c.text.matches('\n').count();
            for k in 0..=extra {
                let l = c.line - 1 + k;
                if l < n_lines {
                    comment_on_line[l] = true;
                }
            }
        }

        let mut code_on_line = vec![false; n_lines];
        let mut depth_at_line = vec![0usize; n_lines];
        let mut depth = 0usize;
        let mut line = 0usize;
        depth_at_line[0] = 0;
        for ch in code.chars() {
            match ch {
                '\n' => {
                    line += 1;
                    if line < n_lines {
                        depth_at_line[line] = depth;
                    }
                }
                '{' => depth += 1,
                '}' => depth = depth.saturating_sub(1),
                c if !c.is_whitespace() => code_on_line[line] = true,
                _ => {}
            }
        }

        let norm_path = path.replace('\\', "/");
        // Files under a `tests` directory (integration tests, witness
        // files) are test code wall to wall — mask every line so the
        // per-line rules skip them, same as a `#[cfg(test)] mod` body.
        let test_mask = if norm_path.split('/').any(|c| c == "tests") {
            vec![true; n_lines]
        } else {
            test_region_mask(&code, &line_starts)
        };

        SourceFile {
            path: norm_path,
            raw: raw.to_string(),
            code,
            line_starts,
            test_mask,
            comment_on_line,
            code_on_line,
            depth_at_line,
            comments,
            strings,
        }
    }

    /// 1-based line number of byte offset `off`.
    pub fn line_of(&self, off: usize) -> usize {
        match self.line_starts.binary_search(&off) {
            Ok(i) => i + 1,
            Err(i) => i, // i >= 1 because line_starts[0] == 0
        }
    }

    /// Whether 1-based `line` is inside a `#[cfg(test)]` module body.
    pub fn in_test(&self, line: usize) -> bool {
        self.test_mask.get(line - 1).copied().unwrap_or(false)
    }

    /// The code view of 1-based `line`.
    pub fn code_line(&self, line: usize) -> &str {
        let lo = self.line_starts[line - 1];
        let hi = self
            .line_starts
            .get(line)
            .map(|&h| h.saturating_sub(1))
            .unwrap_or(self.code.len());
        &self.code[lo..hi.max(lo)]
    }

    /// Does the path contain `dir` as a full component?
    pub fn has_component(&self, dir: &str) -> bool {
        self.path.split('/').any(|c| c == dir)
    }

    /// The file name (last component).
    pub fn file_name(&self) -> &str {
        self.path.rsplit('/').next().unwrap_or(&self.path)
    }

    /// Byte offsets (in the code view) of every word-bounded
    /// occurrence of `token`. A boundary is any char that cannot be
    /// part of an identifier.
    pub fn find_word(&self, token: &str) -> Vec<usize> {
        find_word_in(&self.code, token)
    }
}

/// Word-bounded substring search over arbitrary text.
pub fn find_word_in(hay: &str, token: &str) -> Vec<usize> {
    let bytes = hay.as_bytes();
    let is_ident = |b: u8| b == b'_' || b.is_ascii_alphanumeric();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = hay[from..].find(token) {
        let at = from + pos;
        let before_ok = at == 0 || !is_ident(bytes[at - 1]);
        let end = at + token.len();
        let after_ok = end >= bytes.len() || !is_ident(bytes[end]);
        // token itself may contain `::` or `.`; boundaries only apply
        // when the token's own edge chars are identifier-like
        let head_ident = token.bytes().next().map(is_ident).unwrap_or(false);
        let tail_ident = token.bytes().last().map(is_ident).unwrap_or(false);
        if (!head_ident || before_ok) && (!tail_ident || after_ok) {
            out.push(at);
        }
        from = at + token.len().max(1);
    }
    out
}

/// Byte offsets of each 0-based line start.
fn line_starts(raw: &str) -> Vec<usize> {
    let mut v = vec![0usize];
    for (i, b) in raw.bytes().enumerate() {
        if b == b'\n' && i + 1 < raw.len() {
            v.push(i + 1);
        }
    }
    v
}

/// Blank comments and literal contents out of `raw`, preserving byte
/// length and newlines; collect comments and string literals with
/// their starting line.
fn blank_non_code(raw: &str) -> (String, Vec<Comment>, Vec<StrLit>) {
    let b = raw.as_bytes();
    let n = b.len();
    let mut out: Vec<u8> = raw.bytes().collect();
    let mut comments = Vec::new();
    let mut strings = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;

    let blank = |out: &mut [u8], lo: usize, hi: usize| {
        for item in out.iter_mut().take(hi).skip(lo) {
            if *item != b'\n' {
                *item = b' ';
            }
        }
    };

    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let start = i;
            let start_line = line;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            comments.push(Comment {
                line: start_line,
                text: raw[start + 2..i].to_string(),
            });
            blank(&mut out, start, i);
            continue;
        }
        // block comment (nested)
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start = i;
            let start_line = line;
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            let text_end = i.saturating_sub(2).max(start + 2);
            comments.push(Comment {
                line: start_line,
                text: raw[start + 2..text_end].to_string(),
            });
            blank(&mut out, start, i);
            continue;
        }
        // raw string r"..." / r#"..."# (and br variants)
        if (c == b'r' || c == b'b') && raw_string_at(b, i).is_some() {
            let (body_start, hashes) = raw_string_at(b, i).unwrap();
            let start = i;
            let closer = {
                let mut s = String::from("\"");
                for _ in 0..hashes {
                    s.push('#');
                }
                s
            };
            let rest = &raw[body_start..];
            let (body_end, end) = match rest.find(&closer) {
                Some(p) => (body_start + p, body_start + p + closer.len()),
                None => (n, n),
            };
            strings.push(StrLit {
                line,
                off: start,
                text: raw[body_start..body_end].to_string(),
            });
            line += raw[start..end].matches('\n').count();
            blank(&mut out, start, end);
            i = end;
            continue;
        }
        // plain / byte string
        if c == b'"' || (c == b'b' && i + 1 < n && b[i + 1] == b'"') {
            let start = i;
            let start_line = line;
            i += if c == b'b' { 2 } else { 1 };
            let body_start = i;
            let mut closed = false;
            while i < n {
                if b[i] == b'\\' {
                    // an escape can hide a newline (string line
                    // continuation: `\` at end of line) — count it,
                    // or every later comment is attributed low
                    if i + 1 < n && b[i + 1] == b'\n' {
                        line += 1;
                    }
                    i += 2;
                    continue;
                }
                if b[i] == b'"' {
                    i += 1;
                    closed = true;
                    break;
                }
                if b[i] == b'\n' {
                    line += 1;
                }
                i += 1;
            }
            let body_end = if closed { i - 1 } else { i };
            strings.push(StrLit {
                line: start_line,
                off: start,
                text: raw[body_start..body_end].to_string(),
            });
            blank(&mut out, start, i);
            continue;
        }
        // char literal vs lifetime
        if c == b'\'' {
            if let Some(end) = char_literal_end(b, i) {
                blank(&mut out, i, end);
                i = end;
                continue;
            }
            // lifetime: leave as code
            i += 1;
            continue;
        }
        i += 1;
    }
    let code = String::from_utf8(out).expect("blanking preserves utf8 boundaries");
    (code, comments, strings)
}

/// If a raw (byte) string literal starts at `i`, return
/// (offset of first body byte, number of `#`s).
fn raw_string_at(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    if j >= b.len() || b[j] != b'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j < b.len() && b[j] == b'"' {
        Some((j + 1, hashes))
    } else {
        None
    }
}

/// If a char literal starts at `i` (a `'`), return the offset just
/// past its closing quote; `None` for lifetimes.
fn char_literal_end(b: &[u8], i: usize) -> Option<usize> {
    let n = b.len();
    if i + 1 >= n {
        return None;
    }
    if b[i + 1] == b'\\' {
        // escaped char: skip to the closing quote
        let mut j = i + 2;
        while j < n && b[j] != b'\'' && b[j] != b'\n' {
            j += 1;
        }
        if j < n && b[j] == b'\'' {
            return Some(j + 1);
        }
        return None;
    }
    // unescaped: exactly one char then a quote, else it's a lifetime
    let mut j = i + 1;
    // advance one utf8 char
    j += 1;
    while j < n && (b[j] & 0xC0) == 0x80 {
        j += 1;
    }
    if j < n && b[j] == b'\'' {
        Some(j + 1)
    } else {
        None
    }
}

/// Mark every 0-based line inside a `#[cfg(test)] mod … { … }` body.
fn test_region_mask(code: &str, line_starts: &[usize]) -> Vec<bool> {
    let n_lines = line_starts.len();
    let mut mask = vec![false; n_lines];
    let mut from = 0usize;
    while let Some(p) = code[from..].find("#[cfg(test)]") {
        let attr_at = from + p;
        from = attr_at + 1;
        // skip whitespace and further attributes to the next token
        let bytes = code.as_bytes();
        let mut j = attr_at + "#[cfg(test)]".len();
        loop {
            while j < bytes.len() && (bytes[j] as char).is_whitespace() {
                j += 1;
            }
            if j < bytes.len() && bytes[j] == b'#' {
                // another attribute: skip to its closing ']'
                while j < bytes.len() && bytes[j] != b']' {
                    j += 1;
                }
                j = (j + 1).min(bytes.len());
                continue;
            }
            break;
        }
        if !code[j..].starts_with("mod") && !code[j..].starts_with("pub mod") {
            continue;
        }
        // find the module's opening brace, then its match
        let open = match code[j..].find('{') {
            Some(o) => j + o,
            None => continue, // `mod x;` — out-of-line, nothing to mask
        };
        let mut depth = 0usize;
        let mut close = code.len();
        for (k, ch) in code[open..].char_indices() {
            match ch {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        close = open + k;
                        break;
                    }
                }
                _ => {}
            }
        }
        let first = offset_line(line_starts, open);
        let last = offset_line(line_starts, close);
        for item in mask.iter_mut().take(last.min(n_lines)).skip(first - 1) {
            *item = true;
        }
    }
    mask
}

/// 1-based line of a byte offset.
fn offset_line(line_starts: &[usize], off: usize) -> usize {
    match line_starts.binary_search(&off) {
        Ok(i) => i + 1,
        Err(i) => i,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blanks_comments_and_strings_keeps_lines() {
        let src = "let a = \"HashMap\"; // HashMap here\nlet b = 1;\n";
        let f = SourceFile::parse("x.rs", src);
        assert_eq!(f.code.len(), src.len());
        assert!(!f.code.contains("HashMap"));
        assert!(f.code.contains("let b = 1;"));
        assert_eq!(f.comments.len(), 1);
        assert_eq!(f.comments[0].line, 1);
        assert!(f.comments[0].text.contains("HashMap here"));
    }

    #[test]
    fn nested_block_comments_and_raw_strings() {
        let src = "/* a /* b */ c */ fn x() {}\nlet r = r#\"un\"safe\"#;\n";
        let f = SourceFile::parse("x.rs", src);
        assert!(f.code.contains("fn x()"));
        assert!(!f.code.contains("safe\""));
        // the nested comment was blanked entirely, `fn x` survived
        assert!(!f.code.contains("a /* b"));
    }

    #[test]
    fn string_line_continuations_keep_comment_lines_aligned() {
        // `\` at end of line inside a string hides a newline from the
        // escape-skipping lexer; comment attribution must still match
        let src = "let s = \"a \\\n   b\";\n// on line three\nlet t = 1;\n";
        let f = SourceFile::parse("x.rs", src);
        assert_eq!(f.comments.len(), 1);
        assert_eq!(f.comments[0].line, 3, "{:?}", f.comments[0]);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\nlet c = 'y';\n";
        let f = SourceFile::parse("x.rs", src);
        assert!(f.code.contains("&'a str"));
        assert!(!f.code.contains("'y'"));
    }

    #[test]
    fn test_mask_covers_cfg_test_mod() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}\n";
        let f = SourceFile::parse("x.rs", src);
        assert!(!f.in_test(1));
        assert!(f.in_test(4));
        assert!(!f.in_test(6));
    }

    #[test]
    fn string_literals_are_collected_with_lines_and_offsets() {
        let src = "let a = \"mlp\";\nlet r = r#\"cnn2\"#;\nlet b = b\"raw\";\n";
        let f = SourceFile::parse("x.rs", src);
        let texts: Vec<&str> = f.strings.iter().map(|s| s.text.as_str()).collect();
        assert_eq!(texts, ["mlp", "cnn2", "raw"]);
        assert_eq!(f.strings[0].line, 1);
        assert_eq!(f.strings[1].line, 2);
        assert_eq!(&src[f.strings[0].off..][..5], "\"mlp\"");
    }

    #[test]
    fn tests_dir_paths_are_fully_masked() {
        let src = "fn helper() {}\n#[test]\nfn t() { helper(); }\n";
        let f = SourceFile::parse("rust/tests/no_alloc.rs", src);
        assert!(f.in_test(1) && f.in_test(3));
        let g = SourceFile::parse("rust/src/lib.rs", src);
        assert!(!g.in_test(1));
    }

    #[test]
    fn word_boundaries() {
        let hits = find_word_in("HashMap XHashMap HashMapX HashMap::new", "HashMap");
        assert_eq!(hits.len(), 2);
    }
}
