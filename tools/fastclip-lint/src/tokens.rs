//! A dependency-free token stream over the blanked code view.
//!
//! The code view (see `source.rs`) already has comments and literal
//! contents spaced out, so lexing it is trivial: maximal identifier
//! runs become `Ident` tokens, every other non-whitespace byte is a
//! one-byte `Punct`. Offsets index the code view directly, which is
//! byte-for-byte aligned with the raw file — a token's `start` is
//! valid in both.
//!
//! On top of the stream live the span-arithmetic helpers the item
//! index and call graph are built from: matching-delimiter search and
//! top-level argument splitting. These are pure index computations on
//! immutable buffers, which makes them cheap to run under miri (the
//! CI lane does).

/// Token classes. The lexer never fails: anything that is not an
/// identifier (or whitespace) is a punct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// `[A-Za-z_][A-Za-z0-9_]*` — keywords included.
    Ident,
    /// A single non-identifier, non-whitespace byte (`{`, `:`, …).
    Punct(u8),
}

/// One token: kind plus its byte span in the code view.
#[derive(Debug, Clone, Copy)]
pub struct Tok {
    pub kind: TokKind,
    /// Byte offset of the first byte.
    pub start: usize,
    /// Byte offset one past the last byte.
    pub end: usize,
}

impl Tok {
    /// The token's text within `code`.
    pub fn text<'a>(&self, code: &'a str) -> &'a str {
        &code[self.start..self.end]
    }

    /// Is this an ident with exactly this text?
    pub fn is_ident(&self, code: &str, word: &str) -> bool {
        self.kind == TokKind::Ident && self.text(code) == word
    }

    /// Is this a punct with exactly this byte?
    pub fn is_punct(&self, ch: u8) -> bool {
        self.kind == TokKind::Punct(ch)
    }
}

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

fn is_ident_start(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphabetic()
}

/// Lex the code view into a token stream. Numeric literals come out
/// as `Ident` runs too (they start with a digit, so `is_ident` with a
/// word never matches them accidentally, and the rules only compare
/// against known names).
pub fn lex(code: &str) -> Vec<Tok> {
    let b = code.as_bytes();
    let n = b.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        if is_ident_start(c) || c.is_ascii_digit() {
            let start = i;
            while i < n && is_ident_byte(b[i]) {
                i += 1;
            }
            out.push(Tok { kind: TokKind::Ident, start, end: i });
            continue;
        }
        out.push(Tok { kind: TokKind::Punct(c), start: i, end: i + 1 });
        i += 1;
    }
    out
}

/// Index of the token matching the opening delimiter at `toks[open]`
/// (`(`, `[`, or `{`). `None` if unbalanced before the stream ends.
pub fn matching_delim(toks: &[Tok], open: usize) -> Option<usize> {
    let close = match toks.get(open)?.kind {
        TokKind::Punct(b'(') => b')',
        TokKind::Punct(b'[') => b']',
        TokKind::Punct(b'{') => b'}',
        _ => return None,
    };
    let opener = match toks[open].kind {
        TokKind::Punct(c) => c,
        TokKind::Ident => return None,
    };
    let mut depth = 0usize;
    for (k, t) in toks.iter().enumerate().skip(open) {
        match t.kind {
            TokKind::Punct(c) if c == opener => depth += 1,
            TokKind::Punct(c) if c == close => {
                depth -= 1;
                if depth == 0 {
                    return Some(k);
                }
            }
            _ => {}
        }
    }
    None
}

/// Given token indices `(open, close)` of a call's parens, split the
/// argument list at top-level commas. Returns byte spans (in the code
/// view) of each argument, trimmed of surrounding whitespace. Nesting
/// of all three bracket kinds is respected; `<` generics are not
/// tracked (comma-splitting inside a generic argument would need a
/// full parser — the rules that consume this only look at leading
/// path idents, which survive).
pub fn split_args(code: &str, toks: &[Tok], open: usize, close: usize) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    if close <= open + 1 {
        return spans; // `()`
    }
    let mut depth = 0usize;
    let mut arg_start = toks[open].end;
    for t in &toks[open + 1..close] {
        match t.kind {
            TokKind::Punct(b'(') | TokKind::Punct(b'[') | TokKind::Punct(b'{') => depth += 1,
            TokKind::Punct(b')') | TokKind::Punct(b']') | TokKind::Punct(b'}') => {
                depth = depth.saturating_sub(1)
            }
            TokKind::Punct(b',') if depth == 0 => {
                spans.push(trim_span(code, arg_start, t.start));
                arg_start = t.end;
            }
            _ => {}
        }
    }
    spans.push(trim_span(code, arg_start, toks[close].start));
    // a lone trailing comma yields an empty final span — drop it
    if let Some(&(lo, hi)) = spans.last() {
        if lo >= hi {
            spans.pop();
        }
    }
    spans
}

/// Shrink `[lo, hi)` past surrounding ASCII whitespace.
pub fn trim_span(code: &str, mut lo: usize, mut hi: usize) -> (usize, usize) {
    let b = code.as_bytes();
    while lo < hi && b[lo].is_ascii_whitespace() {
        lo += 1;
    }
    while hi > lo && b[hi - 1].is_ascii_whitespace() {
        hi -= 1;
    }
    (lo, hi)
}

/// First token index at or after byte offset `off`.
pub fn tok_at_or_after(toks: &[Tok], off: usize) -> usize {
    toks.partition_point(|t| t.start < off)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts<'a>(code: &'a str, toks: &[Tok]) -> Vec<&'a str> {
        toks.iter().map(|t| t.text(code)).collect()
    }

    #[test]
    fn lex_spans_are_exact() {
        let code = "fn add(a: usize) -> usize { a + 1 }";
        let toks = lex(code);
        assert_eq!(
            texts(code, &toks),
            ["fn", "add", "(", "a", ":", "usize", ")", "-", ">", "usize", "{", "a", "+", "1", "}"]
        );
        for t in &toks {
            assert!(t.start < t.end && t.end <= code.len());
            assert!(!t.text(code).contains(' '));
        }
    }

    #[test]
    fn lex_underscores_and_digits() {
        let code = "let _x2 = v0[1];";
        let toks = lex(code);
        assert!(toks[1].is_ident(code, "_x2"));
        assert!(toks[3].is_ident(code, "v0"));
    }

    #[test]
    fn matching_delim_nested() {
        let code = "f(a, g(b, c), [d])";
        let toks = lex(code);
        // toks: f ( a , g ( b , c ) , [ d ] )
        assert_eq!(matching_delim(&toks, 1), Some(14));
        assert_eq!(matching_delim(&toks, 5), Some(9));
        assert_eq!(matching_delim(&toks, 11), Some(13));
        assert_eq!(matching_delim(&toks, 0), None);
    }

    #[test]
    fn matching_delim_unbalanced_is_none() {
        let code = "f(a";
        let toks = lex(code);
        assert_eq!(matching_delim(&toks, 1), None);
    }

    #[test]
    fn split_args_top_level_only() {
        let code = "call(a, g(b, c), [d, e], { f })";
        let toks = lex(code);
        let close = matching_delim(&toks, 1).unwrap();
        let args: Vec<&str> = split_args(code, &toks, 1, close)
            .into_iter()
            .map(|(lo, hi)| &code[lo..hi])
            .collect();
        assert_eq!(args, ["a", "g(b, c)", "[d, e]", "{ f }"]);
    }

    #[test]
    fn split_args_empty_and_trailing_comma() {
        let code = "f() g(x,)";
        let toks = lex(code);
        let c1 = matching_delim(&toks, 1).unwrap();
        assert!(split_args(code, &toks, 1, c1).is_empty());
        let o2 = 4;
        let c2 = matching_delim(&toks, o2).unwrap();
        let args = split_args(code, &toks, o2, c2);
        assert_eq!(args.len(), 1);
        assert_eq!(&code[args[0].0..args[0].1], "x");
    }

    #[test]
    fn tok_at_or_after_boundaries() {
        let code = "ab  cd";
        let toks = lex(code);
        assert_eq!(tok_at_or_after(&toks, 0), 0);
        assert_eq!(tok_at_or_after(&toks, 1), 1);
        assert_eq!(tok_at_or_after(&toks, 4), 1);
        assert_eq!(tok_at_or_after(&toks, 6), 2);
    }
}
