//! CLI driver: `fastclip-lint <path>...` lints every `.rs` file under
//! the given paths (as one tree, so cross-file rules see everything)
//! and exits nonzero on findings.
//!
//! ```text
//! fastclip-lint [--format text|json|sarif] [--baseline FILE] <path>...
//! fastclip-lint --write-baseline FILE <path>...
//! fastclip-lint --list-rules [--format json]
//! ```
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/IO error. CI runs the
//! text format as the gating job and the sarif format for code
//! scanning annotations (see .github/workflows/ci.yml).

use std::path::PathBuf;
use std::process::ExitCode;

use fastclip_lint::{sarif, Finding};

struct Cli {
    format: String,
    baseline: Option<PathBuf>,
    write_baseline: Option<PathBuf>,
    list_rules: bool,
    paths: Vec<PathBuf>,
}

fn parse_cli(args: &[String]) -> Result<Cli, String> {
    let mut cli = Cli {
        format: "text".to_string(),
        baseline: None,
        write_baseline: None,
        list_rules: false,
        paths: Vec::new(),
    };
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--format" => {
                let v = it.next().ok_or("--format needs a value")?;
                if !matches!(v.as_str(), "text" | "json" | "sarif") {
                    return Err(format!("unknown format {v:?} (text | json | sarif)"));
                }
                cli.format = v.clone();
            }
            "--baseline" => {
                cli.baseline = Some(PathBuf::from(it.next().ok_or("--baseline needs a file")?));
            }
            "--write-baseline" => {
                cli.write_baseline =
                    Some(PathBuf::from(it.next().ok_or("--write-baseline needs a file")?));
            }
            "--list-rules" => cli.list_rules = true,
            other if other.starts_with('-') => {
                return Err(format!("unknown flag {other:?}"));
            }
            path => cli.paths.push(PathBuf::from(path)),
        }
    }
    Ok(cli)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        usage();
        return ExitCode::SUCCESS;
    }
    let cli = match parse_cli(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("fastclip-lint: {e}");
            usage();
            return ExitCode::from(2);
        }
    };

    if cli.list_rules {
        list_rules(&cli.format);
        return ExitCode::SUCCESS;
    }
    if cli.paths.is_empty() {
        usage();
        return ExitCode::from(2);
    }

    let (findings, n_files) = match fastclip_lint::run_paths(&cli.paths) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("fastclip-lint: error: {e}");
            return ExitCode::from(2);
        }
    };

    if let Some(path) = &cli.write_baseline {
        let b = fastclip_lint::baseline_counts(&findings);
        if let Err(e) = std::fs::write(path, fastclip_lint::render_baseline(&b)) {
            eprintln!("fastclip-lint: cannot write baseline {}: {e}", path.display());
            return ExitCode::from(2);
        }
        println!(
            "fastclip-lint: baseline of {} finding(s) written to {}",
            findings.len(),
            path.display()
        );
        return ExitCode::SUCCESS;
    }

    let findings = match &cli.baseline {
        None => findings,
        Some(path) => match std::fs::read_to_string(path) {
            Ok(text) => {
                fastclip_lint::apply_baseline(findings, &fastclip_lint::parse_baseline(&text))
            }
            Err(e) => {
                eprintln!("fastclip-lint: cannot read baseline {}: {e}", path.display());
                return ExitCode::from(2);
            }
        },
    };

    emit(&cli.format, &findings, n_files);
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn emit(format: &str, findings: &[Finding], n_files: usize) {
    match format {
        "json" => println!("{}", sarif::to_json(findings)),
        "sarif" => println!("{}", sarif::to_sarif(findings)),
        _ => {
            for f in findings {
                println!("{f}");
            }
            let n_rules = sarif::rule_meta().len();
            if findings.is_empty() {
                println!("fastclip-lint: {n_files} files clean ({n_rules} rules active)");
            } else {
                println!(
                    "fastclip-lint: {} finding(s) in {n_files} files ({n_rules} rules active)",
                    findings.len()
                );
            }
        }
    }
}

fn list_rules(format: &str) {
    let meta = sarif::rule_meta();
    if format == "json" {
        let mut s = String::from("[");
        for (i, (id, desc, scope)) in meta.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n  {{\"id\": \"{}\", \"description\": \"{}\", \"scope\": \"{}\"}}",
                sarif::esc(id),
                sarif::esc(desc),
                sarif::esc(scope)
            ));
        }
        s.push_str("\n]");
        println!("{s}");
        return;
    }
    for (id, desc, scope) in &meta {
        println!("{id:<26} {desc}");
        println!("{:<26}   where: {scope}", "");
    }
}

fn usage() {
    eprintln!(
        "usage: fastclip-lint [--format text|json|sarif] [--baseline FILE] <path>...\n\
         \x20      fastclip-lint --write-baseline FILE <path>...\n\
         \x20      fastclip-lint --list-rules [--format json]"
    );
}
