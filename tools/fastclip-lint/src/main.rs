//! CLI driver: `fastclip-lint <path>...` lints every `.rs` file under
//! the given paths and exits nonzero on findings. `--list-rules`
//! prints the registry. CI runs `cargo run -p fastclip-lint -- rust/src`
//! as a required job.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        usage();
        return ExitCode::SUCCESS;
    }
    if args.iter().any(|a| a == "--list-rules") {
        for rule in fastclip_lint::rules::all() {
            println!("{:<22} {}", rule.id(), rule.describe());
        }
        println!(
            "{:<22} {}",
            fastclip_lint::LINT_ALLOW,
            "allow-list hygiene: every `lint: allow` must name a real rule, carry a reason, and suppress something"
        );
        return ExitCode::SUCCESS;
    }
    let paths: Vec<PathBuf> = args.iter().map(PathBuf::from).collect();
    if paths.is_empty() {
        usage();
        return ExitCode::from(2);
    }
    match fastclip_lint::run_paths(&paths) {
        Ok((findings, n_files)) => {
            for f in &findings {
                println!("{f}");
            }
            let n_rules = fastclip_lint::rules::all().len() + 1; // + lint-allow
            if findings.is_empty() {
                println!("fastclip-lint: {n_files} files clean ({n_rules} rules active)");
                ExitCode::SUCCESS
            } else {
                println!(
                    "fastclip-lint: {} finding(s) in {n_files} files ({n_rules} rules active)",
                    findings.len()
                );
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("fastclip-lint: error: {e}");
            ExitCode::from(2)
        }
    }
}

fn usage() {
    eprintln!(
        "usage: fastclip-lint <path>...   lint every .rs file under the paths\n\
         \x20      fastclip-lint --list-rules"
    );
}
