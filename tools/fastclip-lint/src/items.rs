//! The item index: a structural pass over one file's token stream
//! that records fn / impl / trait / mod spans, resolves `use`
//! declarations to crate module paths, and extracts the *dispatch
//! arms* of `match` expressions over the native step-method enum
//! (`Kind::Reweight`, `Kind::MultiLoss`, …). The call graph
//! (`callgraph.rs`) builds its nodes from this index.
//!
//! Everything here is token arithmetic over the blanked code view —
//! no AST, no dependencies. The parse is deliberately forgiving:
//! anything it cannot shape (exotic generics, macros) is skipped, and
//! the rules that consume the index are written so that a skipped
//! item weakens precision, never soundness of the build itself.

use crate::source::SourceFile;
use crate::tokens::{lex, matching_delim, Tok, TokKind};
use std::collections::BTreeMap;

/// Variant names of the native step-method dispatch enum
/// (`runtime/native/mod.rs::Kind`). A match arm whose pattern names
/// one of these through a `::` path is a *dispatch arm* — the unit at
/// which the dp-flow rule checks that each batched clipping method
/// applies nu on its own leaf path.
pub const DISPATCH_KINDS: [&str; 8] = [
    "Fwd",
    "NonPrivate",
    "Naive1",
    "Reweight",
    "ReweightGram",
    "ReweightDirect",
    "ReweightPallas",
    "MultiLoss",
];

/// Dispatch kinds that are exempt from the nu obligation: the forward
/// probe, the non-private route, and the naive per-example loop
/// (which clips at the coordinator seam, not in the arm).
pub const EXEMPT_KINDS: [&str; 3] = ["Fwd", "NonPrivate", "Naive1"];

/// One `fn` item.
#[derive(Debug)]
pub struct FnItem {
    pub name: String,
    /// 1-based line of the `fn` keyword.
    pub line: usize,
    /// Byte offset of the `fn` keyword.
    pub sig_start: usize,
    /// Byte span of the `{ … }` body, braces included. `None` for
    /// bodiless declarations (trait method requirements).
    pub body: Option<(usize, usize)>,
    /// Declared inside a test region (cfg(test) mod or tests/ dir).
    pub is_test: bool,
    /// Dispatch arms of method-kind `match`es in the body, nested.
    pub arms: Vec<Arm>,
}

/// One dispatch arm of a method-kind `match`.
#[derive(Debug)]
pub struct Arm {
    /// 1-based line the pattern starts on.
    pub line: usize,
    /// Code-view text of the pattern.
    pub pattern: String,
    /// `DISPATCH_KINDS` members named in the pattern via a `::` path.
    pub kinds: Vec<String>,
    /// Byte span of the arm body (block braces included).
    pub body: (usize, usize),
    /// Nested dispatch arms inside this arm's body.
    pub children: Vec<Arm>,
}

/// One `impl` block.
#[derive(Debug)]
pub struct ImplItem {
    /// `Some("Trait")` for `impl Trait for Type`, `None` for
    /// inherent impls.
    pub trait_name: Option<String>,
    pub type_name: String,
    pub line: usize,
    /// Byte span of the `{ … }` body.
    pub body: (usize, usize),
}

/// One `trait` declaration.
#[derive(Debug)]
pub struct TraitItem {
    pub name: String,
    pub line: usize,
    pub body: (usize, usize),
    /// Methods declared with `;` (no default body) — the surface a
    /// conforming impl must provide.
    pub required_fns: Vec<String>,
}

/// One `mod` item (inline or out-of-line).
#[derive(Debug)]
pub struct ModItem {
    pub name: String,
    pub line: usize,
}

/// The index for one file.
#[derive(Debug)]
pub struct FileItems {
    /// Token stream over the code view (shared with the call graph).
    pub toks: Vec<Tok>,
    pub fns: Vec<FnItem>,
    pub impls: Vec<ImplItem>,
    pub traits: Vec<TraitItem>,
    pub mods: Vec<ModItem>,
    /// `use` resolution: visible leaf name → crate module paths it
    /// was imported from (`use crate::privacy::calibrate_sigma` maps
    /// `calibrate_sigma` → `["privacy"]`). A name imported twice
    /// keeps every path.
    pub uses: BTreeMap<String, Vec<Vec<String>>>,
}

/// Build the index for one parsed file.
pub fn index(f: &SourceFile) -> FileItems {
    let code = &f.code;
    let toks = lex(code);
    let mut fns = Vec::new();
    let mut impls = Vec::new();
    let mut traits = Vec::new();
    let mut mods = Vec::new();
    let mut uses: BTreeMap<String, Vec<Vec<String>>> = BTreeMap::new();

    let mut k = 0usize;
    while k < toks.len() {
        let t = &toks[k];
        if t.kind != TokKind::Ident {
            k += 1;
            continue;
        }
        match t.text(code) {
            "fn" => {
                if let Some((item, next)) = parse_fn(f, code, &toks, k) {
                    fns.push(item);
                    k = next;
                    continue;
                }
            }
            "impl" => {
                if let Some((item, next)) = parse_impl(f, code, &toks, k) {
                    impls.push(item);
                    // do not skip the body: nested fns are indexed too
                    k = next;
                    continue;
                }
            }
            "trait" => {
                if let Some((item, next)) = parse_trait(f, code, &toks, k) {
                    traits.push(item);
                    k = next;
                    continue;
                }
            }
            "mod" => {
                if let Some(name) = toks.get(k + 1).filter(|n| n.kind == TokKind::Ident) {
                    mods.push(ModItem {
                        name: name.text(code).to_string(),
                        line: f.line_of(t.start),
                    });
                }
            }
            "use" => {
                if let Some(next) = parse_use(code, &toks, k, &mut uses) {
                    k = next;
                    continue;
                }
            }
            _ => {}
        }
        k += 1;
    }

    // trait required surface: bodiless fns declared inside the body
    for tr in &mut traits {
        tr.required_fns = fns
            .iter()
            .filter(|fi| fi.body.is_none() && fi.sig_start > tr.body.0 && fi.sig_start < tr.body.1)
            .map(|fi| fi.name.clone())
            .collect();
    }

    // dispatch arms per fn
    for fi in &mut fns {
        if let Some(body) = fi.body {
            fi.arms = dispatch_arms(f, code, &toks, body);
        }
    }

    FileItems { toks, fns, impls, traits, mods, uses }
}

impl FileItems {
    /// Fns whose sig starts inside `span` (used for impl membership).
    pub fn fns_in(&self, span: (usize, usize)) -> impl Iterator<Item = &FnItem> {
        self.fns.iter().filter(move |f| f.sig_start > span.0 && f.sig_start < span.1)
    }
}

/// Parse the `fn` at token `k`. Returns the item and the token index
/// to resume scanning from (just after the signature — the body is
/// scanned again by the main loop so nested items are found, which is
/// harmless because `fn` cannot nest a second `fn` signature between
/// its own `fn` keyword and its opening brace).
fn parse_fn(f: &SourceFile, code: &str, toks: &[Tok], k: usize) -> Option<(FnItem, usize)> {
    let name_tok = toks.get(k + 1)?;
    if name_tok.kind != TokKind::Ident {
        return None; // `fn(` pointer type
    }
    let name = name_tok.text(code).to_string();
    // scan forward for the body `{` or the decl-terminating `;`,
    // skipping (…)/[…] nesting (parameter lists, defaults)
    let mut depth = 0usize;
    let mut j = k + 2;
    let mut body = None;
    while j < toks.len() {
        match toks[j].kind {
            TokKind::Punct(b'(') | TokKind::Punct(b'[') => depth += 1,
            TokKind::Punct(b')') | TokKind::Punct(b']') => depth = depth.saturating_sub(1),
            TokKind::Punct(b'{') if depth == 0 => {
                let close = matching_delim(toks, j)?;
                body = Some((toks[j].start, toks[close].end));
                j += 1; // resume inside the body
                break;
            }
            TokKind::Punct(b';') if depth == 0 => {
                j += 1;
                break;
            }
            _ => {}
        }
        j += 1;
    }
    let line = f.line_of(toks[k].start);
    Some((
        FnItem {
            name,
            line,
            sig_start: toks[k].start,
            body,
            is_test: f.in_test(line),
            arms: Vec::new(),
        },
        j,
    ))
}

/// Parse the `impl` at token `k`; resume just inside its body.
fn parse_impl(f: &SourceFile, code: &str, toks: &[Tok], k: usize) -> Option<(ImplItem, usize)> {
    // find the body `{` at top level; `where` clauses appear before it
    let mut j = k + 1;
    let mut open = None;
    let mut depth = 0usize;
    while j < toks.len() {
        match toks[j].kind {
            TokKind::Punct(b'(') | TokKind::Punct(b'[') => depth += 1,
            TokKind::Punct(b')') | TokKind::Punct(b']') => depth = depth.saturating_sub(1),
            TokKind::Punct(b'{') if depth == 0 => {
                open = Some(j);
                break;
            }
            TokKind::Punct(b';') if depth == 0 => return None,
            _ => {}
        }
        j += 1;
    }
    let open = open?;
    let close = matching_delim(toks, open)?;
    // idents at angle-depth 0 between `impl` and `{` (or `where`),
    // split at a top-level `for`
    let mut angle = 0isize;
    let mut before_for: Vec<&str> = Vec::new();
    let mut after_for: Vec<&str> = Vec::new();
    let mut seen_for = false;
    for t in &toks[k + 1..open] {
        match t.kind {
            TokKind::Punct(b'<') => angle += 1,
            TokKind::Punct(b'>') => angle -= 1,
            TokKind::Ident if angle == 0 => {
                let w = t.text(code);
                if w == "where" {
                    break;
                }
                if w == "for" {
                    seen_for = true;
                } else if seen_for {
                    after_for.push(w);
                } else {
                    before_for.push(w);
                }
            }
            _ => {}
        }
    }
    let (trait_name, type_words) = if seen_for {
        (before_for.last().map(|s| s.to_string()), after_for)
    } else {
        (None, before_for)
    };
    let type_name = type_words.last()?.to_string();
    Some((
        ImplItem {
            trait_name,
            type_name,
            line: f.line_of(toks[k].start),
            body: (toks[open].start, toks[close].end),
        },
        open + 1,
    ))
}

/// Parse the `trait` at token `k`; resume just inside its body.
fn parse_trait(f: &SourceFile, code: &str, toks: &[Tok], k: usize) -> Option<(TraitItem, usize)> {
    let name_tok = toks.get(k + 1)?;
    if name_tok.kind != TokKind::Ident {
        return None;
    }
    let mut j = k + 2;
    while j < toks.len() && !toks[j].is_punct(b'{') {
        if toks[j].is_punct(b';') {
            return None; // `trait X;` cannot occur, but stay safe
        }
        j += 1;
    }
    if j >= toks.len() {
        return None;
    }
    let close = matching_delim(toks, j)?;
    Some((
        TraitItem {
            name: name_tok.text(code).to_string(),
            line: f.line_of(toks[k].start),
            body: (toks[j].start, toks[close].end),
            required_fns: Vec::new(),
        },
        j + 1,
    ))
}

/// Parse a `use …;` declaration into the alias map. Handles paths,
/// nested `{ … }` groups, `as` renames, and `self` in groups; glob
/// imports are ignored. Returns the token index after the `;`.
fn parse_use(
    code: &str,
    toks: &[Tok],
    k: usize,
    uses: &mut BTreeMap<String, Vec<Vec<String>>>,
) -> Option<usize> {
    // find the terminating `;`
    let mut end = k + 1;
    let mut depth = 0usize;
    while end < toks.len() {
        match toks[end].kind {
            TokKind::Punct(b'{') => depth += 1,
            TokKind::Punct(b'}') => depth = depth.saturating_sub(1),
            TokKind::Punct(b';') if depth == 0 => break,
            _ => {}
        }
        end += 1;
    }
    if end >= toks.len() {
        return None;
    }
    let mut prefix: Vec<String> = Vec::new();
    walk_use(code, &toks[k + 1..end], &mut prefix, uses);
    Some(end + 1)
}

/// Recursive walk of one use-tree level. `toks` is the slice for this
/// level; `prefix` the path segments accumulated so far.
fn walk_use(
    code: &str,
    toks: &[Tok],
    prefix: &mut Vec<String>,
    uses: &mut BTreeMap<String, Vec<Vec<String>>>,
) {
    // split this level at top-level commas (only inside groups)
    let mut start = 0usize;
    let mut depth = 0usize;
    let mut parts: Vec<(usize, usize)> = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        match t.kind {
            TokKind::Punct(b'{') => depth += 1,
            TokKind::Punct(b'}') => depth = depth.saturating_sub(1),
            TokKind::Punct(b',') if depth == 0 => {
                parts.push((start, i));
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push((start, toks.len()));

    for (lo, hi) in parts {
        let part = &toks[lo..hi];
        if part.is_empty() {
            continue;
        }
        // leading path segments up to a group `{`, a glob `*`, or end
        let mut segs: Vec<String> = Vec::new();
        let mut i = 0usize;
        let mut alias: Option<String> = None;
        let mut group_at: Option<usize> = None;
        while i < part.len() {
            match part[i].kind {
                TokKind::Ident => {
                    let w = part[i].text(code);
                    if w == "as" {
                        if let Some(a) = part.get(i + 1) {
                            alias = Some(a.text(code).to_string());
                        }
                        break;
                    }
                    segs.push(w.to_string());
                    i += 1;
                }
                TokKind::Punct(b':') => i += 1,
                TokKind::Punct(b'{') => {
                    group_at = Some(i);
                    break;
                }
                TokKind::Punct(b'*') => {
                    segs.clear();
                    break;
                }
                _ => break,
            }
        }
        if let Some(g) = group_at {
            let depth_before = prefix.len();
            prefix.extend(segs.iter().cloned());
            // strip the outer braces of the group
            let inner_hi = part.len() - usize::from(part.last().is_some_and(|t| t.is_punct(b'}')));
            walk_use(code, &part[g + 1..inner_hi], prefix, uses);
            prefix.truncate(depth_before);
            continue;
        }
        if segs.is_empty() {
            continue; // glob or unparsable
        }
        let leaf = segs.last().cloned().filter(|s| s != "self");
        let visible = alias.or(leaf.clone()).or_else(|| prefix.last().cloned());
        let Some(visible) = visible else { continue };
        // full module path: prefix + segs, minus crate-ish roots and
        // the leaf itself (the leaf is the item, not a module)
        let mut path: Vec<String> = prefix
            .iter()
            .chain(segs.iter())
            .filter(|s| !matches!(s.as_str(), "crate" | "self" | "super" | "std" | "core" | "alloc"))
            .cloned()
            .collect();
        if leaf.is_some() && !path.is_empty() {
            path.pop();
        }
        uses.entry(visible).or_default().push(path);
    }
}

/// Extract nested dispatch arms of every method-kind `match` inside
/// `body` (byte span). Only matches with at least one arm naming a
/// `DISPATCH_KINDS` member are kept.
fn dispatch_arms(f: &SourceFile, code: &str, toks: &[Tok], body: (usize, usize)) -> Vec<Arm> {
    let lo = crate::tokens::tok_at_or_after(toks, body.0);
    let hi = crate::tokens::tok_at_or_after(toks, body.1);
    collect_matches(f, code, toks, lo, hi)
}

/// Scan tokens `[lo, hi)` for `match` expressions and return the
/// dispatch arms found at this level (arms recurse for nesting).
fn collect_matches(f: &SourceFile, code: &str, toks: &[Tok], lo: usize, hi: usize) -> Vec<Arm> {
    let mut out = Vec::new();
    let mut k = lo;
    while k < hi {
        if !toks[k].is_ident(code, "match") {
            k += 1;
            continue;
        }
        // scrutinee runs to the first `{` at delimiter depth 0
        let mut depth = 0usize;
        let mut open = None;
        let mut j = k + 1;
        while j < hi {
            match toks[j].kind {
                TokKind::Punct(b'(') | TokKind::Punct(b'[') => depth += 1,
                TokKind::Punct(b')') | TokKind::Punct(b']') => depth = depth.saturating_sub(1),
                TokKind::Punct(b'{') if depth == 0 => {
                    open = Some(j);
                    break;
                }
                _ => {}
            }
            j += 1;
        }
        let Some(open) = open else {
            k += 1;
            continue;
        };
        let Some(close) = matching_delim(toks, open) else {
            k += 1;
            continue;
        };
        let arms = parse_arms(f, code, toks, open + 1, close);
        if arms.iter().any(|a| !a.kinds.is_empty()) {
            out.extend(arms);
            k = close + 1; // arms own everything inside — do not rescan
        } else {
            k = open + 1; // not a dispatch match: rescan inside for one
        }
    }
    out
}

/// Parse the arms of one match body (`toks[lo..hi]`).
fn parse_arms(f: &SourceFile, code: &str, toks: &[Tok], lo: usize, hi: usize) -> Vec<Arm> {
    let mut arms = Vec::new();
    let mut k = lo;
    while k < hi {
        // pattern: up to `=>` at delimiter depth 0
        let pat_start = k;
        let mut depth = 0usize;
        let mut arrow = None;
        while k < hi {
            match toks[k].kind {
                TokKind::Punct(b'(') | TokKind::Punct(b'[') | TokKind::Punct(b'{') => depth += 1,
                TokKind::Punct(b')') | TokKind::Punct(b']') | TokKind::Punct(b'}') => {
                    depth = depth.saturating_sub(1)
                }
                TokKind::Punct(b'=')
                    if depth == 0 && toks.get(k + 1).is_some_and(|t| t.is_punct(b'>')) =>
                {
                    arrow = Some(k);
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        let Some(arrow) = arrow else { break };
        if arrow == pat_start {
            break; // malformed
        }
        let pat_span = (toks[pat_start].start, toks[arrow - 1].end);
        let mut kinds: Vec<String> = Vec::new();
        for (i, t) in toks[pat_start..arrow].iter().enumerate() {
            let global = pat_start + i;
            if t.kind == TokKind::Ident
                && global >= 2
                && toks[global - 1].is_punct(b':')
                && toks[global - 2].is_punct(b':')
            {
                let w = t.text(code);
                if DISPATCH_KINDS.contains(&w) && !kinds.iter().any(|k| k == w) {
                    kinds.push(w.to_string());
                }
            }
        }
        // body: a block, or an expression up to a top-level `,`
        k = arrow + 2;
        if k >= hi {
            break;
        }
        let (body_span, next) = if toks[k].is_punct(b'{') {
            match matching_delim(toks, k) {
                Some(c) => ((toks[k].start, toks[c].end), c + 1),
                None => break,
            }
        } else {
            let start = toks[k].start;
            let mut depth = 0usize;
            let mut j = k;
            while j < hi {
                match toks[j].kind {
                    TokKind::Punct(b'(') | TokKind::Punct(b'[') | TokKind::Punct(b'{') => {
                        depth += 1
                    }
                    TokKind::Punct(b')') | TokKind::Punct(b']') | TokKind::Punct(b'}') => {
                        depth = depth.saturating_sub(1)
                    }
                    TokKind::Punct(b',') if depth == 0 => break,
                    _ => {}
                }
                j += 1;
            }
            ((start, toks[j.saturating_sub(1).max(k)].end), j)
        };
        let children = {
            let c_lo = crate::tokens::tok_at_or_after(toks, body_span.0);
            let c_hi = crate::tokens::tok_at_or_after(toks, body_span.1);
            collect_matches(f, code, toks, c_lo, c_hi)
        };
        arms.push(Arm {
            line: f.line_of(pat_span.0),
            pattern: code[pat_span.0..pat_span.1].to_string(),
            kinds,
            body: body_span,
            children,
        });
        // skip a trailing comma after a block body
        let mut next = next;
        if next < hi && toks[next].is_punct(b',') {
            next += 1;
        }
        k = next;
    }
    arms
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(path: &str, src: &str) -> (SourceFile, FileItems) {
        let f = SourceFile::parse(path, src);
        let idx = index(&f);
        (f, idx)
    }

    #[test]
    fn fns_impls_traits_mods_are_indexed() {
        let src = "\
mod util;
pub trait Fam {
    fn norms(&self);
    fn route(&self) -> usize { 0 }
}
pub struct A;
impl Fam for A {
    fn norms(&self) {}
}
impl A {
    fn extra(&self) {}
}
fn free() {}
";
        let (_f, idx) = parse("rust/src/x.rs", src);
        let names: Vec<&str> = idx.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, ["norms", "route", "norms", "extra", "free"]);
        assert_eq!(idx.traits.len(), 1);
        assert_eq!(idx.traits[0].required_fns, ["norms"]);
        assert_eq!(idx.impls.len(), 2);
        assert_eq!(idx.impls[0].trait_name.as_deref(), Some("Fam"));
        assert_eq!(idx.impls[0].type_name, "A");
        assert_eq!(idx.impls[1].trait_name, None);
        assert_eq!(idx.mods.len(), 1);
        // impl membership
        let in_first: Vec<&str> =
            idx.fns_in(idx.impls[0].body).map(|f| f.name.as_str()).collect();
        assert_eq!(in_first, ["norms"]);
    }

    #[test]
    fn generic_impl_for_resolves_trait_and_type() {
        let src = "impl<T: Clone> Route<T> for Spec<T> where T: Send { fn go(&self) {} }";
        let (_f, idx) = parse("x.rs", src);
        assert_eq!(idx.impls[0].trait_name.as_deref(), Some("Route"));
        assert_eq!(idx.impls[0].type_name, "Spec");
    }

    #[test]
    fn use_groups_and_aliases_resolve() {
        let src = "\
use crate::privacy::{calibrate_sigma, rdp::RdpAccountant as Acc};
use super::store::GradVec;
use std::collections::BTreeMap;
";
        let (_f, idx) = parse("x.rs", src);
        assert_eq!(idx.uses["calibrate_sigma"], vec![vec!["privacy".to_string()]]);
        assert_eq!(
            idx.uses["Acc"],
            vec![vec!["privacy".to_string(), "rdp".to_string()]]
        );
        assert_eq!(idx.uses["GradVec"], vec![vec!["store".to_string()]]);
        assert_eq!(idx.uses["BTreeMap"], vec![vec!["collections".to_string()]]);
    }

    #[test]
    fn dispatch_arms_nest_and_classify() {
        let src = "\
fn run(&self) {
    match self.kind {
        Kind::Fwd => fwd(),
        Kind::Reweight | Kind::ReweightGram => {
            prefix();
            match self.kind {
                Kind::Reweight => leaf_a(),
                _ => leaf_b(),
            }
        }
        Kind::MultiLoss => multi(),
        _ => other(),
    }
}
";
        let (_f, idx) = parse("rust/src/runtime/native/mod.rs", src);
        let arms = &idx.fns[0].arms;
        assert_eq!(arms.len(), 4);
        assert_eq!(arms[0].kinds, ["Fwd"]);
        assert_eq!(arms[1].kinds, ["Reweight", "ReweightGram"]);
        assert_eq!(arms[1].children.len(), 2);
        assert_eq!(arms[1].children[0].kinds, ["Reweight"]);
        assert!(arms[1].children[1].kinds.is_empty());
        assert_eq!(arms[2].kinds, ["MultiLoss"]);
        assert!(arms[3].kinds.is_empty());
    }

    #[test]
    fn non_dispatch_matches_are_ignored_but_scanned_inside() {
        let src = "\
fn pick(x: Option<u8>) -> u8 {
    match x {
        Some(v) => match self.kind { Kind::Fwd => v, _ => 0 },
        None => 0,
    }
}
";
        let (_f, idx) = parse("x.rs", src);
        let arms = &idx.fns[0].arms;
        // the outer Option match is not a dispatch match; the inner
        // Kind match is found by rescanning inside it
        assert_eq!(arms.len(), 2);
        assert_eq!(arms[0].kinds, ["Fwd"]);
    }

    #[test]
    fn bodiless_trait_fns_have_no_body() {
        let src = "trait T { fn a(&self); }";
        let (_f, idx) = parse("x.rs", src);
        assert!(idx.fns[0].body.is_none());
    }
}
