//! Machine-readable output: plain JSON findings and SARIF 2.1.0.
//!
//! Hand-rolled (the tool is dependency-free by charter). The SARIF
//! subset emitted is the minimum GitHub code scanning consumes: one
//! run, one driver with rule metadata, one result per finding with a
//! physical location. Output is deterministic: findings arrive
//! already sorted by the engine, rules are listed in registry order.

use crate::rules;
use crate::Finding;

/// Escape a string for inclusion inside a JSON string literal.
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Findings as a JSON array of `{rule, path, line, message}` objects.
pub fn to_json(findings: &[Finding]) -> String {
    let mut out = String::from("[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n  {{\"rule\": \"{}\", \"path\": \"{}\", \"line\": {}, \"message\": \"{}\"}}",
            esc(f.rule),
            esc(&f.path),
            f.line,
            esc(&f.message)
        ));
    }
    out.push_str(if findings.is_empty() { "]" } else { "\n]" });
    out
}

/// (id, description, scope) for every registered rule, including the
/// engine's own allow-hygiene rule.
pub fn rule_meta() -> Vec<(&'static str, String, String)> {
    let mut out: Vec<(&'static str, String, String)> = Vec::new();
    for r in rules::all() {
        out.push((r.id(), r.describe().to_string(), r.scope().to_string()));
    }
    for r in rules::tree_rules() {
        out.push((r.id(), r.describe().to_string(), r.scope().to_string()));
    }
    out.push((
        crate::LINT_ALLOW,
        "lint: allow(...) annotations must name a known rule, carry a `-- reason`, and \
         suppress at least one finding"
            .to_string(),
        "every linted file (the engine's own allow-hygiene check)".to_string(),
    ));
    out
}

/// Findings as a SARIF 2.1.0 log (one run, rule metadata included).
pub fn to_sarif(findings: &[Finding]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"$schema\": \"https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/Schemata/sarif-schema-2.1.0.json\",\n");
    s.push_str("  \"version\": \"2.1.0\",\n");
    s.push_str("  \"runs\": [\n    {\n");
    s.push_str("      \"tool\": {\n        \"driver\": {\n");
    s.push_str("          \"name\": \"fastclip-lint\",\n");
    s.push_str("          \"informationUri\": \"DESIGN.md\",\n");
    s.push_str("          \"rules\": [\n");
    let meta = rule_meta();
    for (i, (id, desc, scope)) in meta.iter().enumerate() {
        s.push_str(&format!(
            "            {{\"id\": \"{}\", \"shortDescription\": {{\"text\": \"{}\"}}, \
             \"help\": {{\"text\": \"scope: {}\"}}}}{}\n",
            esc(id),
            esc(desc),
            esc(scope),
            if i + 1 < meta.len() { "," } else { "" }
        ));
    }
    s.push_str("          ]\n        }\n      },\n");
    s.push_str("      \"results\": [\n");
    for (i, f) in findings.iter().enumerate() {
        s.push_str(&format!(
            "        {{\"ruleId\": \"{}\", \"level\": \"error\", \
             \"message\": {{\"text\": \"{}\"}}, \"locations\": [{{\
             \"physicalLocation\": {{\"artifactLocation\": {{\"uri\": \"{}\"}}, \
             \"region\": {{\"startLine\": {}}}}}}}]}}{}\n",
            esc(f.rule),
            esc(&f.message),
            esc(&f.path),
            f.line,
            if i + 1 < findings.len() { "," } else { "" }
        ));
    }
    s.push_str("      ]\n    }\n  ]\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Finding> {
        vec![Finding {
            path: "rust/src/runtime/x.rs".to_string(),
            line: 3,
            rule: "no-hash-container",
            message: "a \"quoted\" message\nwith a newline".to_string(),
        }]
    }

    #[test]
    fn json_escapes_and_shapes() {
        let j = to_json(&sample());
        assert!(j.contains("\\\"quoted\\\""));
        assert!(j.contains("\\n"));
        assert!(j.contains("\"line\": 3"));
        assert_eq!(to_json(&[]), "[]");
    }

    #[test]
    fn sarif_lists_every_rule_and_result() {
        let s = to_sarif(&sample());
        assert!(s.contains("\"version\": \"2.1.0\""));
        for (id, _, _) in rule_meta() {
            assert!(s.contains(&format!("\"id\": \"{id}\"")), "{id}");
        }
        assert!(s.contains("\"startLine\": 3"));
        assert!(s.contains("\"ruleId\": \"no-hash-container\""));
    }

    #[test]
    fn esc_control_chars() {
        assert_eq!(esc("a\u{1}b"), "a\\u0001b");
        assert_eq!(esc("t\\p"), "t\\\\p");
    }
}
