//! fastclip-lint: machine-checks for the repo's two prose contracts —
//! the bitwise-determinism contract (`rust/src/runtime/native/gemm.rs`
//! module docs) and the DP-flow invariant (per-example gradients reach
//! the optimizer only through the clip/noise pipeline). See DESIGN.md
//! §"Machine-checked invariants" for the rule list and the etiquette
//! for allow-list annotations.
//!
//! Suppression grammar (checked, not free-form):
//!
//! ```text
//! // lint: allow(<rule-id>) -- <reason>         (next code line)
//! // lint: allow-file(<rule-id>) -- <reason>    (whole file)
//! ```
//!
//! An allow without a reason, naming an unknown rule, or suppressing
//! nothing is itself a finding (rule `lint-allow`), so the allow-list
//! can only shrink to what is genuinely explained and genuinely used.

pub mod callgraph;
pub mod items;
pub mod rules;
pub mod sarif;
pub mod source;
pub mod tokens;

use source::SourceFile;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One lint hit. `line` is 1-based.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub path: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// Rule id of the engine's own allow-hygiene findings.
pub const LINT_ALLOW: &str = "lint-allow";

#[derive(Debug)]
struct Allow {
    rule: String,
    /// line the annotation comment sits on (for reporting)
    decl_line: usize,
    /// code line the allow applies to (`None` = whole file)
    target_line: Option<usize>,
    has_reason: bool,
    used: bool,
}

/// Lint one file's text under a given (possibly virtual) path. The
/// path drives the rules' directory scoping, so fixtures can exercise
/// path-scoped rules from anywhere on disk. Tree rules see a
/// single-file tree — multi-file facts need [`lint_sources`].
pub fn lint_source(path: &str, text: &str) -> Vec<Finding> {
    lint_sources(&[(path.to_string(), text.to_string())])
}

/// Lint a set of files as one tree: per-file rules run on each file,
/// tree rules (dp-flow, family-contract, sensitivity-consistency) run
/// once over the call graph of all of them, and allow annotations are
/// applied per file to both kinds. Findings come back grouped in
/// input-file order, sorted by line within a file.
pub fn lint_sources(inputs: &[(String, String)]) -> Vec<Finding> {
    let files: Vec<SourceFile> =
        inputs.iter().map(|(p, t)| SourceFile::parse(p, t)).collect();

    // per-file rules
    let mut per_file: Vec<Vec<Finding>> = files
        .iter()
        .map(|f| {
            let mut v = Vec::new();
            for rule in rules::all() {
                rule.check(f, &mut v);
            }
            v
        })
        .collect();

    // tree rules over the whole set, findings routed to their file
    let tree = callgraph::Tree::build(&files);
    let mut tree_findings: Vec<Finding> = Vec::new();
    for rule in rules::tree_rules() {
        rule.check(&tree, &mut tree_findings);
    }
    for tf in tree_findings {
        match files.iter().position(|f| f.path == tf.path) {
            Some(i) => per_file[i].push(tf),
            None => per_file.last_mut().expect("nonempty input").push(tf),
        }
    }

    let mut out = Vec::new();
    for (f, raw) in files.iter().zip(per_file) {
        out.extend(filter_file(f, raw));
    }
    out
}

/// Apply per-file post-processing to one file's raw findings: dedup
/// by (rule, line), honor `lint: allow` annotations, and emit the
/// allow-hygiene findings.
fn filter_file(f: &SourceFile, mut raw: Vec<Finding>) -> Vec<Finding> {
    // one finding per (rule, line): several tokens of the same rule on
    // one line are one problem, and one allow covers them
    raw.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    raw.dedup_by(|a, b| a.line == b.line && a.rule == b.rule);

    let mut allows = parse_allows(f);
    let mut out: Vec<Finding> = Vec::new();
    'finding: for fi in raw {
        for al in allows.iter_mut() {
            if al.rule != fi.rule {
                continue;
            }
            let hits = match al.target_line {
                None => true,
                Some(t) => t == fi.line,
            };
            if hits {
                al.used = true;
                continue 'finding;
            }
        }
        out.push(fi);
    }

    // allow-list hygiene: every annotation must name a real rule,
    // carry a reason, and suppress something
    let known: Vec<&'static str> = rules::all()
        .iter()
        .map(|r| r.id())
        .chain(rules::tree_rules().iter().map(|r| r.id()))
        .chain(std::iter::once(LINT_ALLOW))
        .collect();
    for al in &allows {
        if !known.contains(&al.rule.as_str()) {
            out.push(Finding {
                path: f.path.clone(),
                line: al.decl_line,
                rule: LINT_ALLOW,
                message: format!(
                    "allow names unknown rule {:?} (known: {})",
                    al.rule,
                    known.join(", ")
                ),
            });
            continue;
        }
        if !al.has_reason {
            out.push(Finding {
                path: f.path.clone(),
                line: al.decl_line,
                rule: LINT_ALLOW,
                message: format!(
                    "allow({}) has no reason — write `// lint: allow({}) -- <why this is sound>`",
                    al.rule, al.rule
                ),
            });
        }
        if !al.used {
            out.push(Finding {
                path: f.path.clone(),
                line: al.decl_line,
                rule: LINT_ALLOW,
                message: format!(
                    "allow({}) suppresses nothing here — remove the stale annotation",
                    al.rule
                ),
            });
        }
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Extract `lint: allow(...)` / `lint: allow-file(...)` annotations.
fn parse_allows(f: &SourceFile) -> Vec<Allow> {
    let mut out = Vec::new();
    for c in &f.comments {
        let t = c.text.trim();
        let (body, file_scope) = if let Some(rest) = t.strip_prefix("lint: allow-file(") {
            (rest, true)
        } else if let Some(rest) = t.strip_prefix("lint: allow(") {
            (rest, false)
        } else {
            continue;
        };
        let (rule, tail) = match body.split_once(')') {
            Some(x) => x,
            None => ("", body),
        };
        let has_reason = tail
            .trim_start()
            .strip_prefix("--")
            .map(|r| !r.trim().is_empty())
            .unwrap_or(false);
        let target_line = if file_scope {
            None
        } else {
            // the next line carrying code; a trailing comment applies
            // to its own line
            let own = c.line;
            if f.code_on_line.get(own - 1).copied().unwrap_or(false) {
                Some(own)
            } else {
                let mut l = own + 1;
                while l <= f.code_on_line.len()
                    && !f.code_on_line[l - 1]
                {
                    l += 1;
                }
                Some(l)
            }
        };
        out.push(Allow {
            rule: rule.trim().to_string(),
            decl_line: c.line,
            target_line,
            has_reason,
            used: false,
        });
    }
    out
}

/// Lint a file on disk. The path is used as-is for scoping.
pub fn lint_file(path: &Path) -> std::io::Result<Vec<Finding>> {
    let text = std::fs::read_to_string(path)?;
    Ok(lint_source(&path.to_string_lossy(), &text))
}

/// Baseline ratchet: per-(rule, path) finding counts. The baseline
/// file records today's debt; a run may match it but never exceed it,
/// and regenerating with `--write-baseline` after paying debt down
/// shrinks the allowance permanently.
pub type Baseline = BTreeMap<(String, String), usize>;

/// Count findings per (rule, path).
pub fn baseline_counts(findings: &[Finding]) -> Baseline {
    let mut b = Baseline::new();
    for f in findings {
        *b.entry((f.rule.to_string(), f.path.clone())).or_insert(0) += 1;
    }
    b
}

/// Render a baseline as its file format: `count<TAB>rule<TAB>path`
/// lines, sorted (BTreeMap order), `#` comments allowed on read.
pub fn render_baseline(b: &Baseline) -> String {
    let mut s = String::from("# fastclip-lint baseline: count\trule\tpath (ratchet — may shrink, never grow)\n");
    for ((rule, path), count) in b {
        s.push_str(&format!("{count}\t{rule}\t{path}\n"));
    }
    s
}

/// Parse a baseline file. Unparsable lines are ignored (a hand-edited
/// baseline can only lose allowance, never gain it silently).
pub fn parse_baseline(text: &str) -> Baseline {
    let mut b = Baseline::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.splitn(3, '\t');
        let (Some(count), Some(rule), Some(path)) =
            (parts.next(), parts.next(), parts.next())
        else {
            continue;
        };
        let Ok(count) = count.parse::<usize>() else { continue };
        b.insert((rule.to_string(), path.to_string()), count);
    }
    b
}

/// Suppress up to the baselined count of findings per (rule, path) —
/// the first N by the engine's order — and return the excess. New
/// findings in un-baselined buckets always surface.
pub fn apply_baseline(findings: Vec<Finding>, baseline: &Baseline) -> Vec<Finding> {
    let mut budget: Baseline = baseline.clone();
    findings
        .into_iter()
        .filter(|f| {
            match budget.get_mut(&(f.rule.to_string(), f.path.clone())) {
                Some(n) if *n > 0 => {
                    *n -= 1;
                    false
                }
                _ => true,
            }
        })
        .collect()
}

/// Recursively collect `.rs` files under each path (files pass
/// through), sorted so output order is stable across platforms.
pub fn collect_rs_files(paths: &[PathBuf]) -> std::io::Result<Vec<PathBuf>> {
    fn walk(p: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
        if p.is_file() {
            if p.extension().map(|e| e == "rs").unwrap_or(false) {
                out.push(p.to_path_buf());
            }
            return Ok(());
        }
        let mut entries: Vec<PathBuf> = std::fs::read_dir(p)?
            .map(|e| e.map(|e| e.path()))
            .collect::<std::io::Result<_>>()?;
        entries.sort();
        for e in entries {
            let name = e.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name == "target" || name.starts_with('.') {
                continue;
            }
            walk(&e, out)?;
        }
        Ok(())
    }
    let mut out = Vec::new();
    for p in paths {
        walk(p, &mut out)?;
    }
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `paths` as one tree (so cross-file
/// rules see everything at once); returns (findings, files seen).
pub fn run_paths(paths: &[PathBuf]) -> std::io::Result<(Vec<Finding>, usize)> {
    let files = collect_rs_files(paths)?;
    let mut inputs: Vec<(String, String)> = Vec::with_capacity(files.len());
    for file in &files {
        inputs.push((
            file.to_string_lossy().replace('\\', "/"),
            std::fs::read_to_string(file)?,
        ));
    }
    Ok((lint_sources(&inputs), files.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_with_reason_suppresses_next_line() {
        let src = "\
// lint: allow(no-hash-container) -- pinned iteration below
use std::collections::HashMap;
";
        let f = lint_source("rust/src/runtime/x.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn allow_without_reason_is_a_finding() {
        let src = "\
// lint: allow(no-hash-container)
use std::collections::HashMap;
";
        let f = lint_source("rust/src/runtime/x.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, LINT_ALLOW);
        assert!(f[0].message.contains("no reason"));
    }

    #[test]
    fn unused_allow_is_a_finding() {
        let src = "// lint: allow(no-hash-container) -- nothing here uses one\nfn f() {}\n";
        let f = lint_source("rust/src/runtime/x.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("suppresses nothing"));
    }

    #[test]
    fn unknown_rule_in_allow_is_a_finding() {
        let src = "// lint: allow(no-such-rule) -- why not\nfn f() {}\n";
        let f = lint_source("rust/src/runtime/x.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("unknown rule"));
    }

    #[test]
    fn baseline_roundtrip_and_ratchet() {
        let mk = |line| Finding {
            path: "rust/src/runtime/x.rs".to_string(),
            line,
            rule: "no-hash-container",
            message: "m".to_string(),
        };
        let old = vec![mk(1), mk(5)];
        let base = baseline_counts(&old);
        let reparsed = parse_baseline(&render_baseline(&base));
        assert_eq!(base, reparsed);
        // same debt: fully suppressed
        assert!(apply_baseline(old.clone(), &base).is_empty());
        // one new finding in the bucket: exactly the excess surfaces
        let grown = vec![mk(1), mk(5), mk(9)];
        let left = apply_baseline(grown, &base);
        assert_eq!(left.len(), 1);
        assert_eq!(left[0].line, 9);
        // a different rule is not covered
        let other = vec![Finding { rule: "dp-flow", ..mk(2) }];
        assert_eq!(apply_baseline(other, &base).len(), 1);
    }

    #[test]
    fn file_allow_covers_every_hit() {
        let src = "\
// lint: allow-file(no-wallclock-entropy) -- compile telemetry only
use std::time::Instant;
fn t() -> std::time::Instant { Instant::now() }
";
        let f = lint_source("rust/src/runtime/engine.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }
}
