//! A conservative intra-crate call graph with per-node DP *effect
//! summaries*, built from the item index over every linted file.
//!
//! Nodes are non-test functions plus the dispatch arms of the native
//! step-method `match` (so the dp-flow rule can reason about one
//! batched clipping method at a time). Edges are name-resolved: a
//! call `foo(…)` links to every non-test `fn foo` in the linted tree,
//! optionally narrowed by the calling file's `use` map, and `.step(…)`
//! / `.steps(…)` calls are narrowed by their receiver (an `opt`
//! receiver is the optimizer, an `accountant`/`probe`/`acc` receiver
//! is the RDP accountant).
//!
//! Effects are seeded at known sink calls and propagated to fixpoint:
//!
//! | effect              | seeded by                                        |
//! |---------------------|--------------------------------------------------|
//! | writes-GradVec      | `flat_mut` `param_mut` `norms_fill` `set_norms` `set_group_norms` `add_scaled` `add_scaled_params` `grads_from_deltas` `materialize_grad_row` |
//! | applies-nu          | `scale_delta_rows`, `add_scaled`, `add_scaled_params`, `backward_batch`/`grads_from_deltas` with a `Some(…)` nu/scale argument |
//! | adds-noise          | `add_noise_parallel`                             |
//! | charges-accountant  | `.step(`/`.steps(` on an accountant-ish receiver |
//! | steps-optimizer     | `.step(` on an `opt`/`optimizer` receiver        |
//!
//! The asymmetry is deliberate: *positive* edges (nu, noise, charge)
//! are seeded only at precise, distinctively-named sinks, so deleting
//! the real call makes the effect disappear (the rule stays
//! non-vacuous); the *reach* of gradient data is over-approximated
//! (any same-named callee contributes), so a true violation cannot
//! hide behind imprecise resolution. Computing clip factors
//! (`nu_for`) is intentionally not an applies-nu seed — only the
//! scaling of gradient data counts, which is what makes "computed nu
//! but never applied it" detectable.

use crate::items::{self, FileItems};
use crate::source::SourceFile;
use crate::tokens::{matching_delim, tok_at_or_after, Tok, TokKind};
use std::collections::BTreeMap;

/// Effect bitset.
pub type Effects = u8;
pub const WRITES_GRAD: Effects = 1 << 0;
pub const APPLIES_NU: Effects = 1 << 1;
pub const ADDS_NOISE: Effects = 1 << 2;
pub const CHARGES_ACCT: Effects = 1 << 3;
pub const STEPS_OPT: Effects = 1 << 4;

/// Human-readable effect names, bit order.
pub const EFFECT_NAMES: [&str; 5] =
    ["writes-GradVec", "applies-nu", "adds-noise", "charges-accountant", "steps-optimizer"];

/// Candidate narrowing for a resolved call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Restrict {
    /// All same-named fns (then `use`-map narrowed when possible).
    None,
    /// Only fns defined under an `optim` path component.
    Optim,
    /// Only fns defined under a `privacy` path component.
    Privacy,
}

/// One call site inside a node's exclusive region.
#[derive(Debug)]
pub struct CallSite {
    pub callee: String,
    /// 1-based line of the callee token.
    pub line: usize,
    /// Effects this call seeds directly.
    pub seed: Effects,
    restrict: Restrict,
    /// Resolved candidate node indices (filled during build).
    cands: Vec<usize>,
}

/// One call-graph node: a fn, or a dispatch arm of one.
#[derive(Debug)]
pub struct Node {
    pub file: usize,
    /// Defining fn's name (arms share their fn's name).
    pub fn_name: String,
    /// `fn_name` or `fn_name#arm@line` for display in findings.
    pub display: String,
    /// 1-based line of the fn sig or arm pattern.
    pub line: usize,
    /// Dispatch kinds named by this arm's pattern (empty for fns).
    pub kinds: Vec<String>,
    pub is_arm: bool,
    /// Arm with no nested dispatch arms.
    pub is_leaf_arm: bool,
    pub parent: Option<usize>,
    pub children: Vec<usize>,
    /// Effects seeded directly in this node's exclusive region.
    pub own: Effects,
    /// Fixpoint effects: own ∪ children ∪ resolved callees.
    pub reach: Effects,
    /// Fixpoint effects excluding children (this node's code path
    /// only) — what an execution that *reaches but does not enter*
    /// the child arms performs.
    pub excl_reach: Effects,
    pub calls: Vec<CallSite>,
    /// Lines of direct optimizer-step calls in the exclusive region.
    pub opt_step_lines: Vec<usize>,
    /// Lines of direct noise-addition calls in the exclusive region.
    pub noise_lines: Vec<usize>,
}

/// The call graph over one linted tree.
pub struct Tree<'a> {
    pub files: &'a [SourceFile],
    pub items: Vec<FileItems>,
    pub nodes: Vec<Node>,
}

/// Keywords and ubiquitous names never treated as resolvable calls.
const NOT_A_CALL: [&str; 40] = [
    "if", "while", "for", "match", "return", "loop", "as", "in", "move", "ref", "mut", "let",
    "else", "fn", "impl", "pub", "use", "mod", "where", "unsafe", "dyn", "break", "continue",
    "struct", "enum", "trait", "type", "const", "static", "crate", "super", "self", "Self",
    "Some", "Ok", "Err", "None", "assert", "vec", "panic",
];

/// Ubiquitous method names whose name-based resolution would conflate
/// unrelated impls; they are seeded (if a sink) but never resolved.
const NO_RESOLVE: [&str; 36] = [
    "new", "default", "clone", "len", "is_empty", "iter", "iter_mut", "into_iter", "push", "pop",
    "get", "get_mut", "insert", "remove", "contains", "resize", "clear", "fill", "extend",
    "to_string", "to_vec", "into", "from", "unwrap", "unwrap_or", "expect", "map", "and_then",
    "ok_or", "collect", "zip", "enumerate", "min", "max", "sqrt", "abs",
];

impl<'a> Tree<'a> {
    /// Index every file and build the effect-annotated call graph.
    pub fn build(files: &'a [SourceFile]) -> Tree<'a> {
        let items: Vec<FileItems> = files.iter().map(items::index).collect();
        let mut nodes: Vec<Node> = Vec::new();

        for (fi, (f, idx)) in files.iter().zip(items.iter()).enumerate() {
            for func in &idx.fns {
                let Some(body) = func.body else { continue };
                if func.is_test {
                    continue;
                }
                let node_idx = nodes.len();
                nodes.push(Node {
                    file: fi,
                    fn_name: func.name.clone(),
                    display: func.name.clone(),
                    line: func.line,
                    kinds: Vec::new(),
                    is_arm: false,
                    is_leaf_arm: false,
                    parent: None,
                    children: Vec::new(),
                    own: 0,
                    reach: 0,
                    excl_reach: 0,
                    calls: Vec::new(),
                    opt_step_lines: Vec::new(),
                    noise_lines: Vec::new(),
                });
                let mut arm_scans: Vec<(usize, Vec<(usize, usize)>)> = Vec::new();
                let child_extents =
                    add_arm_nodes(&mut nodes, node_idx, fi, &func.name, &func.arms, &mut arm_scans);
                for (arm_idx, regions) in arm_scans {
                    scan_region(&mut nodes, arm_idx, f, &idx.toks, &regions);
                }
                let excl = subtract_spans(body, &child_extents);
                scan_region(&mut nodes, node_idx, f, &idx.toks, &excl);
            }
        }

        let mut tree = Tree { files, items, nodes };
        tree.resolve_calls();
        tree.fixpoint();
        tree
    }

    /// Fill each call site's candidate list.
    fn resolve_calls(&mut self) {
        // name -> fn-node indices (arms are never call targets)
        let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for (i, n) in self.nodes.iter().enumerate() {
            if !n.is_arm {
                by_name.entry(&n.fn_name).or_default().push(i);
            }
        }
        let mut resolved: Vec<(usize, usize, Vec<usize>)> = Vec::new();
        for (ni, node) in self.nodes.iter().enumerate() {
            for (ci, call) in node.calls.iter().enumerate() {
                if NO_RESOLVE.contains(&call.callee.as_str()) {
                    continue;
                }
                let Some(all) = by_name.get(call.callee.as_str()) else { continue };
                let cands: Vec<usize> = match call.restrict {
                    Restrict::Optim => all
                        .iter()
                        .copied()
                        .filter(|&t| self.files[self.nodes[t].file].has_component("optim"))
                        .collect(),
                    Restrict::Privacy => all
                        .iter()
                        .copied()
                        .filter(|&t| self.files[self.nodes[t].file].has_component("privacy"))
                        .collect(),
                    Restrict::None => {
                        let narrowed = self.narrow_by_uses(node.file, &call.callee, all);
                        if narrowed.is_empty() { all.clone() } else { narrowed }
                    }
                };
                resolved.push((ni, ci, cands));
            }
        }
        for (ni, ci, cands) in resolved {
            self.nodes[ni].calls[ci].cands = cands;
        }
    }

    /// Narrow candidates by the calling file's `use` map: keep fns
    /// whose file path matches one of the imported module paths.
    /// Returns empty when the name was not imported (caller falls
    /// back to all candidates).
    fn narrow_by_uses(&self, file: usize, name: &str, all: &[usize]) -> Vec<usize> {
        let Some(paths) = self.items[file].uses.get(name) else {
            return Vec::new();
        };
        all.iter()
            .copied()
            .filter(|&t| {
                let fp = &self.files[self.nodes[t].file];
                paths.iter().any(|p| {
                    p.iter().all(|seg| {
                        fp.has_component(seg) || fp.file_name() == format!("{seg}.rs")
                    })
                })
            })
            .collect()
    }

    /// Propagate effects until stable.
    fn fixpoint(&mut self) {
        let mut changed = true;
        while changed {
            changed = false;
            for i in 0..self.nodes.len() {
                let mut excl = self.nodes[i].own;
                for call in &self.nodes[i].calls {
                    for &t in &call.cands {
                        excl |= self.nodes[t].reach;
                    }
                }
                let mut reach = excl;
                for &c in &self.nodes[i].children.clone() {
                    reach |= self.nodes[c].reach;
                }
                if reach != self.nodes[i].reach || excl != self.nodes[i].excl_reach {
                    self.nodes[i].reach = reach;
                    self.nodes[i].excl_reach = excl;
                    changed = true;
                }
            }
        }
    }

    /// Effects performed on the path that reaches `idx`: the union of
    /// `excl_reach` over the node and its ancestors. For a leaf
    /// dispatch arm this is "everything the method's execution does",
    /// excluding sibling arms.
    pub fn path_effects(&self, idx: usize) -> Effects {
        let mut e = 0;
        let mut at = Some(idx);
        while let Some(i) = at {
            e |= self.nodes[i].excl_reach;
            at = self.nodes[i].parent;
        }
        e
    }

    /// The file a node lives in.
    pub fn file_of(&self, n: &Node) -> &SourceFile {
        &self.files[n.file]
    }
}

/// Recursively add arm nodes under `parent`. Returns the byte extents
/// the arms own (for exclusion from the parent's own region) and
/// appends each arm's (node index, exclusive regions) to `scans` for
/// the caller to run once the whole subtree exists.
fn add_arm_nodes(
    nodes: &mut Vec<Node>,
    parent: usize,
    file: usize,
    fn_name: &str,
    arms: &[items::Arm],
    scans: &mut Vec<(usize, Vec<(usize, usize)>)>,
) -> Vec<(usize, usize)> {
    let mut extents = Vec::new();
    for arm in arms {
        // the arm's extent is its body; the pattern itself carries no
        // calls, and guard expressions are rare enough to ignore
        extents.push(arm.body);
        let idx = nodes.len();
        nodes.push(Node {
            file,
            fn_name: fn_name.to_string(),
            display: format!("{fn_name}#arm@{}", arm.line),
            line: arm.line,
            kinds: arm.kinds.clone(),
            is_arm: true,
            is_leaf_arm: arm.children.is_empty(),
            parent: Some(parent),
            children: Vec::new(),
            own: 0,
            reach: 0,
            excl_reach: 0,
            calls: Vec::new(),
            opt_step_lines: Vec::new(),
            noise_lines: Vec::new(),
        });
        nodes[parent].children.push(idx);
        let child_extents = add_arm_nodes(nodes, idx, file, fn_name, &arm.children, scans);
        scans.push((idx, subtract_spans(arm.body, &child_extents)));
    }
    extents
}

/// Subtract `holes` from `span`, yielding the remaining sub-spans.
fn subtract_spans(span: (usize, usize), holes: &[(usize, usize)]) -> Vec<(usize, usize)> {
    let mut holes: Vec<(usize, usize)> = holes.to_vec();
    holes.sort_unstable();
    let mut out = Vec::new();
    let mut at = span.0;
    for (lo, hi) in holes {
        let lo = lo.max(span.0);
        let hi = hi.min(span.1);
        if lo > at {
            out.push((at, lo));
        }
        at = at.max(hi);
    }
    if at < span.1 {
        out.push((at, span.1));
    }
    out
}

/// Scan `regions` (byte spans of one node's exclusive code) for call
/// sites, seed effects, and record direct opt-step / noise lines.
fn scan_region(
    nodes: &mut [Node],
    node_idx: usize,
    f: &SourceFile,
    toks: &[Tok],
    regions: &[(usize, usize)],
) {
    let code = &f.code;
    for &(lo, hi) in regions {
        let t_lo = tok_at_or_after(toks, lo);
        let t_hi = tok_at_or_after(toks, hi);
        for k in t_lo..t_hi {
            if toks[k].kind != TokKind::Ident {
                continue;
            }
            if !toks.get(k + 1).is_some_and(|t| t.is_punct(b'(')) {
                continue;
            }
            let name = toks[k].text(code);
            if NOT_A_CALL.contains(&name) {
                continue;
            }
            // `fn name(` is a definition, not a call
            if k >= 1 && toks[k - 1].is_ident(code, "fn") {
                continue;
            }
            // receiver: `recv.name(` or `Recv::name(`
            let recv: Option<&str> = if k >= 2 && toks[k - 1].is_punct(b'.') {
                (toks[k - 2].kind == TokKind::Ident).then(|| toks[k - 2].text(code))
            } else if k >= 3 && toks[k - 1].is_punct(b':') && toks[k - 2].is_punct(b':') {
                (toks[k - 3].kind == TokKind::Ident).then(|| toks[k - 3].text(code))
            } else {
                None
            };
            let has_some_arg = matching_delim(toks, k + 1).is_some_and(|close| {
                toks[k + 2..close].iter().any(|t| t.is_ident(code, "Some"))
            });
            let (seed, restrict) = seed_for(name, recv, has_some_arg);
            let line = f.line_of(toks[k].start);
            if seed & STEPS_OPT != 0 {
                nodes[node_idx].opt_step_lines.push(line);
            }
            if seed & ADDS_NOISE != 0 {
                nodes[node_idx].noise_lines.push(line);
            }
            nodes[node_idx].own |= seed;
            nodes[node_idx].calls.push(CallSite {
                callee: name.to_string(),
                line,
                seed,
                restrict,
                cands: Vec::new(),
            });
        }
    }
}

/// Receivers that denote the optimizer / the RDP accountant.
const OPT_RECV: [&str; 3] = ["opt", "optimizer", "Optimizer"];
const ACCT_RECV: [&str; 5] = ["accountant", "acc", "probe", "Accountant", "RdpAccountant"];

/// Effect seeds and candidate narrowing for one call.
fn seed_for(name: &str, recv: Option<&str>, has_some_arg: bool) -> (Effects, Restrict) {
    match name {
        "add_noise_parallel" => (ADDS_NOISE, Restrict::None),
        "scale_delta_rows" => (APPLIES_NU, Restrict::None),
        "add_scaled" | "add_scaled_params" => (APPLIES_NU | WRITES_GRAD, Restrict::None),
        "backward_batch" if has_some_arg => (APPLIES_NU, Restrict::None),
        "grads_from_deltas" if has_some_arg => (APPLIES_NU | WRITES_GRAD, Restrict::None),
        "grads_from_deltas" | "materialize_grad_row" => (WRITES_GRAD, Restrict::None),
        "flat_mut" | "param_mut" | "norms_fill" | "set_norms" | "set_group_norms" => {
            (WRITES_GRAD, Restrict::None)
        }
        "step" if recv.is_some_and(|r| OPT_RECV.contains(&r)) => (STEPS_OPT, Restrict::Optim),
        "step" | "steps" if recv.is_some_and(|r| ACCT_RECV.contains(&r)) => {
            (CHARGES_ACCT, Restrict::Privacy)
        }
        _ => (0, Restrict::None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_all(sources: &[(&str, &str)]) -> Vec<SourceFile> {
        sources.iter().map(|(p, s)| SourceFile::parse(p, s)).collect()
    }

    fn node<'t, 'a>(t: &'t Tree<'a>, name: &str) -> &'t Node {
        t.nodes.iter().find(|n| n.display == name).expect(name)
    }

    #[test]
    fn effects_propagate_through_two_call_hops() {
        let files = parse_all(&[
            (
                "rust/src/coordinator/session.rs",
                "fn step() { produce(); pipeline(); opt.step(h, g); }\n",
            ),
            ("rust/src/runtime/a.rs", "pub fn pipeline() { apply(); }\n"),
            (
                "rust/src/runtime/b.rs",
                "pub fn apply() { g.scale_delta_rows(nu); }\npub fn produce() { out.param_mut(0); }\n",
            ),
        ]);
        let t = Tree::build(&files);
        let s = node(&t, "step");
        assert!(s.reach & APPLIES_NU != 0, "nu through two hops");
        assert!(s.reach & WRITES_GRAD != 0);
        assert!(s.own & STEPS_OPT != 0);
        assert_eq!(s.opt_step_lines.len(), 1);
        assert!(s.reach & ADDS_NOISE == 0);
    }

    #[test]
    fn receiver_narrowing_separates_opt_and_accountant() {
        let files = parse_all(&[(
            "rust/src/coordinator/session.rs",
            "fn go() { accountant.step(q, s); opt.step(h, g); session.step(); }\n",
        )]);
        let t = Tree::build(&files);
        let g = node(&t, "go");
        assert!(g.own & CHARGES_ACCT != 0);
        assert!(g.own & STEPS_OPT != 0);
        // the bare `session.step()` call neither charges nor steps
        assert_eq!(g.opt_step_lines.len(), 1);
    }

    #[test]
    fn arm_nodes_get_exclusive_effects_and_path() {
        let src = "\
fn run_into(&self) {
    stage();
    match self.kind {
        Kind::NonPrivate => { out.grads_from_deltas(x, t, None, g); }
        Kind::ReweightDirect => {
            model.scale_delta_rows(&block, t);
            out.grads_from_deltas(x, t, None, g);
        }
        Kind::ReweightPallas => {
            out.grads_from_deltas(x, t, Some(&block), g);
        }
        _ => {}
    }
}
";
        let files = vec![SourceFile::parse("rust/src/runtime/native/mod.rs", src)];
        let t = Tree::build(&files);
        let direct = t
            .nodes
            .iter()
            .position(|n| n.is_arm && n.kinds == ["ReweightDirect"])
            .unwrap();
        let pallas = t
            .nodes
            .iter()
            .position(|n| n.is_arm && n.kinds == ["ReweightPallas"])
            .unwrap();
        let nonpriv = t
            .nodes
            .iter()
            .position(|n| n.is_arm && n.kinds == ["NonPrivate"])
            .unwrap();
        assert!(t.path_effects(direct) & APPLIES_NU != 0);
        assert!(t.path_effects(pallas) & APPLIES_NU != 0, "Some(&block) seeds nu");
        assert!(t.path_effects(nonpriv) & APPLIES_NU == 0);
        assert!(t.path_effects(nonpriv) & WRITES_GRAD != 0);
        // the fn node's reach unions the arms
        let f = node(&t, "run_into");
        assert!(f.reach & APPLIES_NU != 0);
        assert!(f.excl_reach & APPLIES_NU == 0, "prefix alone applies no nu");
    }

    #[test]
    fn use_map_narrows_candidates() {
        let files = parse_all(&[
            (
                "rust/src/coordinator/session.rs",
                "use crate::privacy::calibrate_sigma;\nfn go() { calibrate_sigma(q); }\n",
            ),
            ("rust/src/privacy/calibrate.rs", "pub fn calibrate_sigma(q: f64) { acc.steps(q, s, n); }\n"),
            ("rust/src/bench/fake.rs", "pub fn calibrate_sigma(q: f64) { g.flat_mut(); }\n"),
        ]);
        let t = Tree::build(&files);
        let g = node(&t, "go");
        assert!(g.reach & CHARGES_ACCT != 0, "resolved into privacy");
        assert!(g.reach & WRITES_GRAD == 0, "bench impostor excluded by use map");
    }

    #[test]
    fn test_fns_are_not_nodes_or_targets() {
        let src = "\
fn real() { helper(); }
fn helper() {}
#[cfg(test)]
mod tests {
    fn helper() { g.flat_mut(); }
    #[test]
    fn t() { real(); }
}
";
        let files = vec![SourceFile::parse("rust/src/runtime/x.rs", src)];
        let t = Tree::build(&files);
        assert_eq!(t.nodes.len(), 2);
        assert!(node(&t, "real").reach & WRITES_GRAD == 0);
    }
}
