//! Fixture UI tests: every rule ships a `bad.rs` that must fail with
//! exactly that rule id and a `good.rs` that must pass, plus the
//! meta-test that the real tree (`rust/src`) lints clean — which also
//! proves there are zero unexplained allow-lists, since a reason-less
//! or unused allow is itself a finding.
//!
//! Fixtures live under `tests/fixtures/<rule-id>/` and are read as
//! text, never compiled. Their first line is a `//@ path: <virtual>`
//! directive giving the path the lint should scope the file under, so
//! path-scoped rules can be exercised from fixture files on disk.

use fastclip_lint::{lint_source, rules, run_paths, LINT_ALLOW};
use std::path::{Path, PathBuf};

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

/// Load a fixture, returning (virtual path, full text).
fn load(rule: &str, which: &str) -> (String, String) {
    let p = fixture_root().join(rule).join(format!("{which}.rs"));
    let text = std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("missing fixture {}: {e}", p.display()));
    let first = text.lines().next().unwrap_or("");
    let vpath = first
        .strip_prefix("//@ path:")
        .map(|s| s.trim().to_string())
        .unwrap_or_else(|| {
            panic!(
                "fixture {} must start with `//@ path: <virtual path>`",
                p.display()
            )
        });
    (vpath, text)
}

fn all_rule_ids() -> Vec<&'static str> {
    let mut ids: Vec<&'static str> = rules::all().iter().map(|r| r.id()).collect();
    ids.push(LINT_ALLOW);
    ids
}

#[test]
fn every_rule_has_a_failing_fixture() {
    for id in all_rule_ids() {
        let (vpath, text) = load(id, "bad");
        let findings = lint_source(&vpath, &text);
        assert!(
            !findings.is_empty(),
            "{id}: bad fixture produced no findings"
        );
        for f in &findings {
            assert_eq!(
                f.rule, id,
                "{id}: bad fixture tripped a different rule: {f}"
            );
        }
    }
}

#[test]
fn every_rule_has_a_passing_fixture() {
    for id in all_rule_ids() {
        let (vpath, text) = load(id, "good");
        let findings = lint_source(&vpath, &text);
        assert!(
            findings.is_empty(),
            "{id}: good fixture should lint clean, got:\n{}",
            render(&findings)
        );
    }
}

#[test]
fn registry_meets_the_rule_floor() {
    // the acceptance criterion: >= 7 rules active — the original six
    // plus the session-seam parameter-mutation rule (the engine's
    // lint-allow hygiene check is on top of these)
    assert!(
        rules::all().len() >= 7,
        "expected >= 7 registered rules, have {}",
        rules::all().len()
    );
    // ids are unique and kebab-case
    let ids = all_rule_ids();
    let mut sorted = ids.clone();
    sorted.sort();
    sorted.dedup();
    assert_eq!(sorted.len(), ids.len(), "duplicate rule ids: {ids:?}");
    for id in ids {
        assert!(
            id.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-'),
            "rule id {id:?} is not kebab-case"
        );
    }
}

#[test]
fn real_tree_lints_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src");
    let (findings, n_files) = run_paths(&[src]).expect("walk rust/src");
    assert!(
        n_files >= 20,
        "expected to see the real tree, linted only {n_files} files"
    );
    assert!(
        findings.is_empty(),
        "rust/src has lint findings (fix them or add a reasoned \
         `// lint: allow(...)`):\n{}",
        render(&findings)
    );
}

fn render(findings: &[fastclip_lint::Finding]) -> String {
    findings
        .iter()
        .map(|f| f.to_string())
        .collect::<Vec<_>>()
        .join("\n")
}
