//! Fixture UI tests: every rule ships a failing fixture set that must
//! fail with exactly that rule id and a passing set that must lint
//! clean, plus the meta-test that the real tree — `rust/src`,
//! `rust/tests`, and this linter's own `src` — lints clean.
//!
//! Fixtures live under `tests/fixtures/<rule-id>/` either as a single
//! `bad.rs` / `good.rs` or as `bad/` / `good/` directories of files
//! (for the interprocedural rules, whose obligations span files).
//! Fixtures are read as text, never compiled. The first line of each
//! file is a `//@ path: <virtual>` directive giving the path the lint
//! should scope the file under, so path-scoped rules can be exercised
//! from fixture files on disk.
//!
//! The four `deleting_*` / `recomputing_*` tests are the non-vacuity
//! proofs from the issue: each takes the REAL tree, surgically removes
//! one privacy-critical call (or recomputes one privacy-critical
//! value), and asserts the matching tree rule fires. If a refactor
//! ever makes one of these pass vacuously, the rule has gone blind.

use fastclip_lint::{lint_sources, rules, run_paths, LINT_ALLOW};
use std::path::{Path, PathBuf};

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

/// Split one fixture file into (virtual path, text).
fn parse_fixture(p: &Path) -> (String, String) {
    let text = std::fs::read_to_string(p)
        .unwrap_or_else(|e| panic!("missing fixture {}: {e}", p.display()));
    let first = text.lines().next().unwrap_or("");
    let vpath = first
        .strip_prefix("//@ path:")
        .map(|s| s.trim().to_string())
        .unwrap_or_else(|| {
            panic!(
                "fixture {} must start with `//@ path: <virtual path>`",
                p.display()
            )
        });
    (vpath, text)
}

/// Load a fixture set: `<rule>/<which>.rs`, or every `.rs` under the
/// `<rule>/<which>/` directory (sorted, so runs are deterministic).
fn load_set(rule: &str, which: &str) -> Vec<(String, String)> {
    let dir = fixture_root().join(rule).join(which);
    if dir.is_dir() {
        let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .filter(|p| p.extension().is_some_and(|x| x == "rs"))
            .collect();
        files.sort();
        assert!(!files.is_empty(), "empty fixture dir {}", dir.display());
        return files.iter().map(|p| parse_fixture(p)).collect();
    }
    let single = fixture_root().join(rule).join(format!("{which}.rs"));
    vec![parse_fixture(&single)]
}

fn all_rule_ids() -> Vec<&'static str> {
    let mut ids: Vec<&'static str> = rules::all().iter().map(|r| r.id()).collect();
    ids.extend(rules::tree_rules().iter().map(|r| r.id()));
    ids.push(LINT_ALLOW);
    ids
}

#[test]
fn every_rule_has_a_failing_fixture() {
    for id in all_rule_ids() {
        let findings = lint_sources(&load_set(id, "bad"));
        assert!(
            !findings.is_empty(),
            "{id}: bad fixture produced no findings"
        );
        for f in &findings {
            assert_eq!(
                f.rule, id,
                "{id}: bad fixture tripped a different rule: {f}"
            );
        }
    }
}

#[test]
fn every_rule_has_a_passing_fixture() {
    for id in all_rule_ids() {
        let findings = lint_sources(&load_set(id, "good"));
        assert!(
            findings.is_empty(),
            "{id}: good fixture should lint clean, got:\n{}",
            render(&findings)
        );
    }
}

#[test]
fn registry_meets_the_rule_floor() {
    // the acceptance criterion: >= 10 rules active — seven per-file
    // rules plus the three interprocedural tree rules (the engine's
    // lint-allow hygiene check is on top of these)
    let n = rules::all().len() + rules::tree_rules().len();
    assert!(n >= 10, "expected >= 10 registered rules, have {n}");
    // ids are unique and kebab-case
    let ids = all_rule_ids();
    let mut sorted = ids.clone();
    sorted.sort();
    sorted.dedup();
    assert_eq!(sorted.len(), ids.len(), "duplicate rule ids: {ids:?}");
    for id in ids {
        assert!(
            id.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-'),
            "rule id {id:?} is not kebab-case"
        );
    }
}

/// The trees the CI lint lane covers: the crate, its integration
/// tests (family-contract witnesses live there), and — dogfooding —
/// this linter's own source.
fn real_roots() -> Vec<PathBuf> {
    let here = Path::new(env!("CARGO_MANIFEST_DIR"));
    vec![
        here.join("../../rust/src"),
        here.join("../../rust/tests"),
        here.join("src"),
    ]
}

#[test]
fn real_tree_lints_clean() {
    let (findings, n_files) = run_paths(&real_roots()).expect("walk the real trees");
    assert!(
        n_files >= 30,
        "expected to see the real tree, linted only {n_files} files"
    );
    assert!(
        findings.is_empty(),
        "the real tree has lint findings (fix them or add a reasoned \
         `// lint: allow(...)`):\n{}",
        render(&findings)
    );
}

// ---- non-vacuity: the tree rules fire on a surgically broken real tree ----

/// Read every real `.rs` file as (path, text) inputs for lint_sources.
fn real_inputs() -> Vec<(String, String)> {
    fn walk(dir: &Path, out: &mut Vec<(String, String)>) {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, out);
            } else if p.extension().is_some_and(|x| x == "rs") {
                let text = std::fs::read_to_string(&p).unwrap();
                out.push((p.to_string_lossy().replace('\\', "/"), text));
            }
        }
    }
    let mut out = Vec::new();
    for root in real_roots() {
        walk(&root, &mut out);
    }
    out
}

/// Apply `edit` to the one input whose path ends with `suffix`.
fn surgery(
    inputs: &mut Vec<(String, String)>,
    suffix: &str,
    edit: impl Fn(&str) -> String,
) {
    let slot = inputs
        .iter_mut()
        .find(|(p, _)| p.ends_with(suffix))
        .unwrap_or_else(|| panic!("no real input ends with {suffix}"));
    let edited = edit(&slot.1);
    assert_ne!(edited, slot.1, "surgery on {suffix} was a no-op");
    slot.1 = edited;
}

/// Remove the statement containing `frag`, searching at or after
/// `after`: from its line start through the next `;`.
fn remove_statement(text: &str, after: &str, frag: &str) -> String {
    let base = text.find(after).unwrap_or_else(|| panic!("marker {after:?} not found"));
    let at = base
        + text[base..]
            .find(frag)
            .unwrap_or_else(|| panic!("{frag:?} not found after {after:?}"));
    let lo = text[..at].rfind('\n').map_or(0, |i| i + 1);
    let hi = at + text[at..].find(';').expect("statement ends") + 1;
    format!("{}{}", &text[..lo], &text[hi..])
}

fn findings_for(inputs: &[(String, String)], rule: &str) -> Vec<String> {
    lint_sources(inputs)
        .iter()
        .filter(|f| f.rule == rule)
        .map(|f| f.to_string())
        .collect()
}

#[test]
fn deleting_the_noise_call_breaks_dp_flow() {
    let mut inputs = real_inputs();
    surgery(&mut inputs, "coordinator/session.rs", |t| {
        remove_statement(t, "fn step", "crate::rng::add_noise_parallel(")
    });
    let hits = findings_for(&inputs, "dp-flow");
    assert!(
        hits.iter().any(|m| m.contains("noise")),
        "removing add_noise_parallel from the session step must trip \
         dp-flow at the optimizer step; got: {hits:?}"
    );
}

#[test]
fn deleting_nu_application_from_reweight_direct_breaks_dp_flow() {
    let mut inputs = real_inputs();
    surgery(&mut inputs, "runtime/native/mod.rs", |t| {
        remove_statement(t, "Kind::ReweightDirect => {", "scale_delta_rows")
    });
    let hits = findings_for(&inputs, "dp-flow");
    assert!(
        hits.iter().any(|m| m.contains("ReweightDirect")),
        "dropping scale_delta_rows from the ReweightDirect arm must \
         trip dp-flow on that arm; got: {hits:?}"
    );
}

#[test]
fn dropping_the_no_alloc_row_breaks_family_contract() {
    let mut inputs = real_inputs();
    surgery(&mut inputs, "tests/no_alloc.rs", |t| {
        t.replace("\"transformer_imdb_b16\"", "\"cnn2_mnist_b16\"")
    });
    let hits = findings_for(&inputs, "family-contract");
    assert!(
        hits.iter().any(|m| m.contains("transformer") && m.contains("no_alloc")),
        "removing the transformer row from no_alloc.rs must trip \
         family-contract; got: {hits:?}"
    );
}

#[test]
fn recomputing_the_clip_bound_breaks_sensitivity_consistency() {
    let mut inputs = real_inputs();
    surgery(&mut inputs, "coordinator/session.rs", |t| {
        t.replace(
            "noise_stddev_for_mean(sigma, sensitivity, tau)",
            "noise_stddev_for_mean(sigma, sensitivity * 1.5, tau)",
        )
    });
    let hits = findings_for(&inputs, "sensitivity-consistency");
    assert!(
        !hits.is_empty(),
        "scaling the clip bound at the calibration site must trip \
         sensitivity-consistency"
    );
}

fn render(findings: &[fastclip_lint::Finding]) -> String {
    findings
        .iter()
        .map(|f| f.to_string())
        .collect::<Vec<_>>()
        .join("\n")
}
