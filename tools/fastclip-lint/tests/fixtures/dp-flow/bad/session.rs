//@ path: rust/src/coordinator/session.rs
//! dp-flow bad: the optimizer consumes produced gradients with no
//! noise-addition reachable on the path, and a second routine adds
//! noise that is never charged to the accountant.

pub fn step(opt: &mut Opt, out: &mut StepOut) {
    compute(out);
    opt.step(&mut params.host, &out.grads);
}

fn compute(out: &mut StepOut) {
    fill(out.grads.flat_mut());
    out.grads.add_scaled(&mat, nu);
}

pub fn noise_unaccounted(g: &mut [f32], opts: &Opts) {
    let noise_std = noise_stddev_for_mean(opts.sigma, opts.clip, opts.tau);
    add_noise_parallel(g, noise_std, opts.seed, 0);
}
