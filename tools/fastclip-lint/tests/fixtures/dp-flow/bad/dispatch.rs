//@ path: rust/src/runtime/native/mod.rs
//! dp-flow bad: the ReweightDirect leaf arm writes gradients but never
//! applies nu — the clip factors were computed (`nu_for`) and dropped,
//! which is exactly the bug class the rule exists for.

pub fn run_into(&self, p: &ClipPolicy, st: &mut Scratch, out: &mut StepOut) {
    match self.kind {
        Kind::NonPrivate => {
            model.grads_from_deltas(x, st, None, &mut out.grads);
        }
        Kind::ReweightDirect => {
            let block = p.nu_for(&norms, st);
            model.grads_from_deltas(x, st, None, &mut out.grads);
        }
        _ => {}
    }
}
