//@ path: rust/src/runtime/native/pipeline.rs
//! The clip edge, two calls below the session: step -> clip_pipeline
//! -> apply_clip -> GradVec::add_scaled. The call graph must carry
//! the applies-nu effect back up through both hops.

pub fn clip_pipeline(acc: &mut GradVec, mat: &Mat, nu: f32) {
    apply_clip(acc, mat, nu);
}

fn apply_clip(acc: &mut GradVec, mat: &Mat, nu: f32) {
    acc.add_scaled(mat, nu);
}
