//@ path: rust/src/runtime/native/mod.rs
//! dp-flow good: every private leaf arm applies nu on its own path —
//! direct row scaling, or the fused Some(nu) reduction.

pub fn run_into(&self, p: &ClipPolicy, st: &mut Scratch, out: &mut StepOut) {
    match self.kind {
        Kind::NonPrivate => {
            model.grads_from_deltas(x, st, None, &mut out.grads);
        }
        Kind::ReweightDirect => {
            model.scale_delta_rows(&block, st);
            model.grads_from_deltas(x, st, None, &mut out.grads);
        }
        Kind::ReweightPallas => {
            model.grads_from_deltas(x, st, Some(&block), &mut out.grads);
        }
        _ => {}
    }
}
