//@ path: rust/src/coordinator/session.rs
//! dp-flow good: the full pipeline. Gradients are produced, the clip
//! edge sits two calls deep (clip_pipeline -> apply_clip, next file),
//! noise is added, the accountant is charged, then the optimizer
//! steps.

pub fn step(&mut self) {
    compute(&mut self.out);
    clip_pipeline(&mut self.out.grads, &self.mat, self.nu);
    let noise_std =
        noise_stddev_for_mean(self.sigma, self.policy.sensitivity(self.n_layers), self.tau);
    add_noise_parallel(self.out.grads.flat_mut(), noise_std, self.seed, self.step);
    self.accountant.step(self.q, self.sigma);
    self.opt.step(&mut self.params.host, &self.out.grads);
}

fn compute(out: &mut StepOut) {
    fill(out.grads.flat_mut());
}
