//@ path: rust/src/coordinator/session.rs
//! sensitivity-consistency bad: the clip bound handed to the noise
//! calibration is recomputed with local arithmetic instead of coming
//! from ClipPolicy::sensitivity / opts.clip, and the stddev handed to
//! the noise sampler is a raw sigma, not a calibrated value.

pub fn build(opts: &Opts) -> f64 {
    let scaled = opts.clip * 1.5;
    noise_stddev_for_mean(opts.sigma, scaled, opts.tau)
}

pub fn noise(g: &mut [f32], opts: &Opts, accountant: &mut Rdp) {
    add_noise_parallel(g, opts.sigma, 7, 0);
    accountant.step(opts.q, opts.sigma);
}
