//@ path: rust/src/coordinator/session.rs
//! sensitivity-consistency good: the calibration clip argument traces
//! to ClipPolicy::sensitivity (or the raw opts.clip), and the sampler
//! receives a value that carries the calibrated name.

pub fn build(opts: &Opts, n_param_layers: usize) -> f64 {
    let sensitivity = match &opts.policy {
        None => opts.clip,
        Some(p) => p.sensitivity(n_param_layers),
    };
    noise_stddev_for_mean(opts.sigma, sensitivity, opts.tau)
}

pub fn noise(g: &mut [f32], noise_std: f64, accountant: &mut Rdp) {
    add_noise_parallel(g, noise_std, 7, 0);
    accountant.step(0.01, 1.1);
}
