//@ path: rust/src/coordinator/serve.rs
// A scheduler "fast path" that pokes weights directly: both the raw
// `&mut …params.host` borrow and the `.mark_dirty()` publication are
// outside the approved set, so each line must be flagged.
fn nudge(params: &mut ParamStore, lr: f32) {
    scale_tensor(&mut params.host[0], lr);
    params.mark_dirty();
}
