//@ path: rust/src/coordinator/serve.rs
// Read-only access to the weight buffers is fine anywhere — only
// mutation is confined to the session/optimizer seam.
fn param_count(params: &ParamStore) -> usize {
    params.host.iter().map(|t| t.len()).sum()
}
