//@ path: rust/src/runtime/native/norms.rs
pub fn sq_norm(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for x in xs {
        acc += x * x;
    }
    acc
}

pub fn total(xs: &[f32]) -> f32 {
    xs.iter().copied().sum::<f32>()
}
