//@ path: rust/src/runtime/native/norms.rs
pub fn sq_norm(xs: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for x in xs {
        acc += (*x as f64) * (*x as f64);
    }
    acc as f32
}
