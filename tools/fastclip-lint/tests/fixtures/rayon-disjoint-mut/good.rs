//@ path: rust/src/runtime/native/scale.rs
use rayon::prelude::*;

pub fn scale(out: &mut [f32], k: f32) {
    out.par_chunks_mut(4096).for_each(|chunk| {
        for x in chunk {
            *x *= k;
        }
    });
}
