//@ path: rust/src/runtime/native/scale.rs
use rayon::prelude::*;
use std::sync::atomic::{AtomicU32, Ordering};

pub fn bump(cells: &[AtomicU32], k: u32) {
    (0..cells.len()).into_par_iter().for_each(|i| {
        cells[i].fetch_add(k, Ordering::Relaxed);
    });
}
