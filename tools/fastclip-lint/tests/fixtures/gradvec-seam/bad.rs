//@ path: rust/src/optim/fancy.rs
use crate::runtime::store::GradVec;

pub fn leak(g: &mut GradVec, raw: &[f32]) {
    let flat = g.flat_mut();
    for (d, s) in flat.iter_mut().zip(raw) {
        *d = *s;
    }
}
