//@ path: rust/src/optim/fancy.rs
use crate::runtime::store::GradVec;

pub fn max_component(g: &GradVec) -> f32 {
    g.flat().iter().copied().fold(0.0, f32::max)
}
