//@ path: rust/src/util/ptr.rs
pub fn write(p: *mut f32, v: f32) {
    // SAFETY: callers pass a pointer derived from a live &mut f32, so
    // it is valid, aligned, and exclusively owned for this write.
    unsafe {
        *p = v;
    }
}
