//@ path: rust/src/util/ptr.rs
pub fn write(p: *mut f32, v: f32) {
    unsafe {
        *p = v;
    }
}
