//@ path: rust/src/runtime/cfg.rs
// lint: allow(no-hash-container)
use std::collections::HashMap;

// lint: allow(no-hash-container) -- nothing on the next line uses one
pub type Names = Vec<String>;

// lint: allow(no-such-rule) -- misspelled rule id
pub const N: usize = 4;

// lint: allow(no-hash-container) -- presence check only, no iteration
pub fn touch(m: &HashMap<String, u32>) -> usize {
    m.len()
}
