//@ path: rust/src/runtime/cfg.rs
// lint: allow-file(no-hash-container) -- keys are collected and sorted
// before any order-dependent use; the map itself is a presence check
use std::collections::HashMap;

pub fn names(m: &HashMap<String, u32>) -> Vec<String> {
    let mut v: Vec<String> = m.keys().cloned().collect();
    v.sort();
    v
}
