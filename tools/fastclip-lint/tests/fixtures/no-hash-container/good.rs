//@ path: rust/src/runtime/registry.rs
use std::collections::BTreeMap;

pub fn order(m: &BTreeMap<String, u32>) -> Vec<String> {
    m.keys().cloned().collect()
}
