//@ path: rust/src/runtime/registry.rs
use std::collections::HashMap;

pub fn order(m: &HashMap<String, u32>) -> Vec<String> {
    m.keys().cloned().collect()
}
