//@ path: rust/src/runtime/hot.rs
pub fn stamp() -> u128 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .expect("clock")
        .as_nanos()
}
