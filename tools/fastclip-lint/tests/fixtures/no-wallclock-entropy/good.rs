//@ path: rust/src/runtime/hot.rs
pub fn stream_id(seed: u64, step: u64) -> u64 {
    seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(step)
}
