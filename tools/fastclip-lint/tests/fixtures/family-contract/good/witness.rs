//@ path: rust/tests/integration.rs

#[test]
fn native_method_matrix_agrees() {
    for config in ["mlp2_mnist_b32", "rnn_seq_b16"] {
        run_matrix(config);
    }
}

#[test]
fn grouped_policies_match_nxbp_oracle() {
    run_oracle("rnn_seq_b16");
}
