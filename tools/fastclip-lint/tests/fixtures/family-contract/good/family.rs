//@ path: rust/src/runtime/native/rnn.rs
//! family-contract good: the rnn family, fully wired — complete
//! ModelFamily impl, registered, and witnessed by all three
//! cross-family test surfaces.

pub trait ModelFamily {
    fn family(&self) -> &'static str;
    fn grad_layout(&self) -> Vec<usize>;
    fn backward_batch(&self, nu: Option<&[f32]>);
}

pub struct RnnSpec;

impl ModelFamily for RnnSpec {
    fn family(&self) -> &'static str {
        "rnn"
    }
    fn grad_layout(&self) -> Vec<usize> {
        Vec::new()
    }
    fn backward_batch(&self, _nu: Option<&[f32]>) {}
}
