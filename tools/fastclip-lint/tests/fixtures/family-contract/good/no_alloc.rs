//@ path: rust/tests/no_alloc.rs

#[test]
fn warm_steps_do_not_allocate() {
    for config in ["mlp2_mnist_b16", "cnn2_mnist_b16", "rnn_seq_b16"] {
        assert_no_alloc(config);
    }
}
