//@ path: rust/src/runtime/native/taps.rs

pub fn builtin() -> FamilyRegistry {
    let mut r = FamilyRegistry::empty();
    r.register("rnn", |cfg| Ok(Box::new(RnnSpec)));
    r
}
