//@ path: rust/src/runtime/native/rnn.rs
//! family-contract bad: the ROADMAP's fourth family, implemented and
//! registered — but nobody added its row to no_alloc.rs, so the
//! steady-state allocation-free guarantee silently excludes it.

pub trait ModelFamily {
    fn family(&self) -> &'static str;
    fn grad_layout(&self) -> Vec<usize>;
    fn backward_batch(&self, nu: Option<&[f32]>);
}

pub struct RnnSpec;

impl ModelFamily for RnnSpec {
    fn family(&self) -> &'static str {
        "rnn"
    }
    fn grad_layout(&self) -> Vec<usize> {
        Vec::new()
    }
    fn backward_batch(&self, _nu: Option<&[f32]>) {}
}
