//@ path: rust/tests/integration.rs
//! The agreement matrix and the policy oracle both cover the rnn
//! family — only the no_alloc witness is missing.

#[test]
fn native_method_matrix_agrees() {
    for config in ["mlp2_mnist_b32", "rnn_seq_b16"] {
        run_matrix(config);
    }
}

#[test]
fn grouped_policies_match_nxbp_oracle() {
    run_oracle("rnn_seq_b16");
}
