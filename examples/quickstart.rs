//! Quickstart: a 30-second tour of the public API.
//!
//!   cargo run --release --example quickstart
//!
//! Loads the AOT artifacts (`make artifacts` first), trains the
//! paper's MLP on synthetic MNIST with full differential privacy via
//! ReweightGP — the paper's fast per-example gradient clipping — and
//! prints the loss curve plus the (epsilon, delta) spent.

use fastclip::coordinator::{train, ClipMethod, TrainOptions};
use fastclip::runtime::{artifacts_dir, Engine};

fn main() -> anyhow::Result<()> {
    fastclip::util::logging::level_from_env();

    // 1. One engine per process: loads manifest.json, compiles HLO
    //    artifacts lazily, caches executables.
    let engine = Engine::from_dir(&artifacts_dir())?;

    // 2. Describe the run. `config` names a (model, dataset, batch)
    //    triple from the manifest; `method` picks the clipping
    //    strategy — Reweight is the paper's contribution.
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 150,
        dataset_n: 2048, // sampling rate q = 32/2048
        lr: 1e-3,
        clip: 1.0,   // per-example L2 clip threshold c
        sigma: 1.1,  // Gaussian noise multiplier
        delta: 1e-5,
        eval_every: 50,
        log_every: 25,
        ..Default::default()
    };

    // 3. Train. Everything below this call is pure Rust + PJRT: no
    //    Python on the request path.
    let report = train(&engine, &opts)?;

    // 4. Privacy accounting comes back with the report.
    let (eps, order) = report.epsilon.expect("private method");
    println!("\n=== quickstart done ===");
    println!("steps          : {}", report.steps);
    println!("final loss(ema): {:.4}", report.final_loss_ema);
    println!("mean step time : {:.2} ms", report.mean_step_ms);
    println!(
        "privacy spent  : ({:.3}, 1e-5)-DP  (best RDP order {})",
        eps, order
    );
    for (step, loss, acc) in &report.eval_points {
        println!("eval @ step {:>4}: loss={:.4} acc={:.3}", step, loss, acc);
    }
    Ok(())
}
