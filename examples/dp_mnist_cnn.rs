//! End-to-end driver (DESIGN.md deliverable): trains the paper's CNN
//! (Sec 6.1.1) on synthetic MNIST for several hundred steps with the
//! full DP pipeline, logging the loss curve, accuracy, privacy budget,
//! per-phase timing, and peak RSS. This run is recorded in
//! EXPERIMENTS.md.
//!
//!   cargo run --release --example dp_mnist_cnn [-- --steps N]
//!
//! It also runs the same schedule with the Pallas-kernel artifact
//! (reweight_pallas) for a composition proof: L1 Pallas kernels inside
//! the L2 step function executed by the L3 coordinator.

use fastclip::coordinator::{train, ClipMethod, TrainOptions};
use fastclip::runtime::{artifacts_dir, Engine};
use fastclip::util;

fn main() -> anyhow::Result<()> {
    fastclip::util::logging::level_from_env();
    let steps: u64 = std::env::args()
        .skip_while(|a| a != "--steps")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);

    let engine = Engine::from_dir(&artifacts_dir())?;

    // The paper's own experimental setting (Sec 6.1): sigma = 0.05,
    // i.e. nominal noise — their evaluation is about training *speed*,
    // and at this noise level the loss curve shows real learning.
    let base = TrainOptions {
        config: "cnn_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps,
        dataset_n: 4096,
        lr: 2e-3,
        clip: 4.0,
        sigma: 0.05, // paper default (Sec 6.1)
        delta: 1e-5,
        optimizer: "adam".into(),
        eval_every: 100,
        log_every: 50,
        seed: 42,
        checkpoint_dir: Some(std::path::PathBuf::from("checkpoints/dp_mnist_cnn")),
        ..Default::default()
    };

    println!("=== DP-CNN end-to-end: ReweightGP, paper setting sigma=0.05 ({} steps) ===", steps);
    let report = train(&engine, &base)?;
    print_report(&report);

    // A privacy-first run: sigma calibrated so the whole schedule fits
    // in a (3.0, 1e-5)-DP budget. Learning is slower — that is the
    // real utility cost of meaningful epsilon at this tiny scale.
    println!("\n=== privacy-first run: calibrated for (3.0, 1e-5)-DP ===");
    let private = TrainOptions {
        target_eps: Some(3.0),
        clip: 1.0,
        lr: 1e-3,
        checkpoint_dir: None,
        eval_every: 200,
        ..base.clone()
    };
    let preport = train(&engine, &private)?;
    println!(
        "calibrated sigma={:.3}; spent ({:.3}, 1e-5)-DP; loss(ema) {:.4} vs {:.4} at sigma=0.05",
        preport.sigma,
        preport.epsilon.unwrap().0,
        preport.final_loss_ema,
        report.final_loss_ema
    );

    println!("\n=== composition proof: same run on the Pallas-kernel artifact (50 steps) ===");
    let pallas = TrainOptions {
        method: ClipMethod::ReweightPallas,
        steps: 50.min(steps),
        eval_every: 0,
        checkpoint_dir: None,
        ..base.clone()
    };
    let preport = train(&engine, &pallas)?;
    println!(
        "pallas backend: loss(ema)={:.4} mean step={:.2} ms (jnp backend was {:.2} ms)",
        preport.final_loss_ema, preport.mean_step_ms, report.mean_step_ms
    );

    // loss-curve summary for EXPERIMENTS.md (decile means)
    println!("\nloss curve (decile means):");
    let n = report.losses.len();
    for d in 0..10 {
        let lo = d * n / 10;
        let hi = ((d + 1) * n / 10).max(lo + 1);
        let mean: f32 =
            report.losses[lo..hi].iter().sum::<f32>() / (hi - lo) as f32;
        println!("  steps {:>4}-{:<4} {:.4}", lo, hi - 1, mean);
    }
    Ok(())
}

fn print_report(r: &fastclip::coordinator::TrainReport) {
    println!("config         : {}", r.config);
    println!("method         : {}", r.method.name());
    println!("final loss(ema): {:.4}", r.final_loss_ema);
    println!("mean step time : {:.2} ms", r.mean_step_ms);
    println!("wall time      : {:.1} s", r.wall_seconds);
    if let Some((eps, order)) = r.epsilon {
        println!("privacy        : ({:.3}, 1e-5)-DP (RDP order {})", eps, order);
    }
    println!("sampling rate q: {:.4}, sigma: {:.3}", r.sampling_rate, r.sigma);
    for (step, loss, acc) in &r.eval_points {
        println!("  eval @ {:>4}: loss={:.4} acc={:.3}", step, loss, acc);
    }
    if let Some(rss) = r.peak_rss_bytes {
        println!("peak RSS       : {}", util::fmt_bytes(rss));
    }
}
