//! Privacy-accounting walkthrough: the RDP machinery of paper Sec 2
//! as a standalone tour — no artifacts needed.
//!
//!   cargo run --release --example accountant_tour

use fastclip::privacy::{
    calibrate_sigma, epsilon_for, max_steps, sgm_rdp_step, RdpAccountant,
};

fn main() {
    println!("=== 1. Per-step RDP of the subsampled Gaussian mechanism ===");
    println!("(q = sampling rate, sigma = noise multiplier)\n");
    println!("  alpha | eps(q=0.01,s=1.1) | eps(q=0.05,s=1.1) | eps(q=0.01,s=0.7)");
    for alpha in [2u32, 4, 8, 16, 32, 64] {
        println!(
            "  {:>5} | {:>17.6} | {:>17.6} | {:>17.6}",
            alpha,
            sgm_rdp_step(0.01, 1.1, alpha),
            sgm_rdp_step(0.05, 1.1, alpha),
            sgm_rdp_step(0.01, 0.7, alpha)
        );
    }

    println!("\n=== 2. Composition over an MNIST-scale run ===");
    println!("(n=60000, batch=600 -> q=0.01; sigma=1.1, delta=1e-5)\n");
    let mut acc = RdpAccountant::new();
    println!("  epoch | steps | epsilon | best alpha");
    for epoch in 1..=15u64 {
        acc.steps(0.01, 1.1, 100);
        if epoch % 3 == 0 || epoch == 1 {
            let (eps, order) = acc.epsilon(1e-5);
            println!(
                "  {:>5} | {:>5} | {:>7.3} | {:>10}",
                epoch,
                acc.steps,
                eps,
                order
            );
        }
    }

    println!("\n=== 3. Calibration: budget -> noise ===\n");
    for (eps, steps) in [(1.0, 1000u64), (2.0, 1000), (4.0, 1000), (2.0, 10000)]
    {
        match calibrate_sigma(0.01, steps, eps, 1e-5) {
            Some(sigma) => println!(
                "  eps<={:<4} over {:>5} steps  =>  sigma = {:.3}  (spends {:.4})",
                eps,
                steps,
                sigma,
                epsilon_for(0.01, sigma, steps, 1e-5)
            ),
            None => println!("  eps<={eps} over {steps} steps: infeasible"),
        }
    }

    println!("\n=== 4. Budget exhaustion: how long can we train? ===\n");
    for sigma in [0.8, 1.1, 1.5, 2.0] {
        let t = max_steps(0.01, sigma, 2.0, 1e-5);
        println!(
            "  sigma={:.1}: {:>6} steps fit in (2.0, 1e-5)-DP  ({} epochs at q=0.01)",
            sigma,
            t,
            t / 100
        );
    }

    println!("\n=== 5. The privacy/utility dial (1000 steps, q=0.01) ===\n");
    println!("  sigma | epsilon(delta=1e-5)");
    for sigma in [0.6, 0.8, 1.0, 1.2, 1.5, 2.0, 3.0, 5.0] {
        println!(
            "  {:>5.1} | {:.3}",
            sigma,
            epsilon_for(0.01, sigma, 1000, 1e-5)
        );
    }
}
