//! Sec 5.6 showcase: differentially private training of a Transformer
//! encoder block (the paper's headline "this now works at practical
//! speed" architecture) on the synthetic IMDB-like sentiment corpus.
//!
//!   cargo run --release --example dp_transformer_imdb [-- --backend auto]
//!
//! Backend resolution mirrors the CLI's `--backend auto`: the PJRT
//! engine when it is compiled in and artifacts are present, the
//! hermetic native backend otherwise — so this runs end-to-end on a
//! bare checkout with no artifacts. It compares all three private
//! strategies on the same schedule so the speed gap — the entire point
//! of the paper — is visible in one run, then finishes the ReweightGP
//! run to a target privacy budget using sigma calibration.

use fastclip::coordinator::{train, ClipMethod, TrainOptions};
use fastclip::runtime::{backend_by_name, Backend};

fn main() -> anyhow::Result<()> {
    fastclip::util::logging::level_from_env();
    let backend_arg = std::env::args()
        .skip_while(|a| a != "--backend")
        .nth(1);
    let backend = backend_by_name(backend_arg.as_deref())?;
    println!("backend: {}", backend.name());

    let base = TrainOptions {
        config: "transformer_imdb_b32".into(),
        steps: 30,
        dataset_n: 2048,
        lr: 1e-3,
        clip: 1.0,
        sigma: 1.1,
        log_every: 0,
        seed: 7,
        ..Default::default()
    };

    println!("=== transformer encoder, one schedule, three strategies ===");
    let mut rows = Vec::new();
    for method in [
        ClipMethod::NonPrivate,
        ClipMethod::Reweight,
        ClipMethod::MultiLoss,
        ClipMethod::NxBp,
    ] {
        let r = train(backend.as_ref(), &TrainOptions { method, ..base.clone() })?;
        println!(
            "  {:<12} mean step {:>9.2} ms   loss(ema) {:.4}",
            method.name(),
            r.mean_step_ms,
            r.final_loss_ema
        );
        rows.push((method, r.mean_step_ms));
    }
    let nxbp = rows
        .iter()
        .find(|(m, _)| *m == ClipMethod::NxBp)
        .unwrap()
        .1;
    let rw = rows
        .iter()
        .find(|(m, _)| *m == ClipMethod::Reweight)
        .unwrap()
        .1;
    println!("  => ReweightGP speedup over nxBP: {:.1}x", nxbp / rw);

    println!("\n=== budget-first training: calibrate sigma for (2.0, 1e-5)-DP ===");
    let budget = TrainOptions {
        method: ClipMethod::Reweight,
        steps: 200,
        target_eps: Some(2.0),
        eval_every: 100,
        log_every: 50,
        ..base
    };
    let r = train(backend.as_ref(), &budget)?;
    let (eps, order) = r.epsilon.unwrap();
    println!(
        "trained {} steps at calibrated sigma={:.3}; spent ({:.3}, 1e-5)-DP (order {})",
        r.steps, r.sigma, eps, order
    );
    Ok(())
}
